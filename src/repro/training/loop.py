"""Training driver: data -> train_step -> CARINA tracking -> checkpoints,
under a fault-tolerance supervisor with elastic re-meshing.

Structure (DESIGN.md §4: a *campaign* of tracked *units*):

    for each unit (N steps):
        decision = controller.decide()            # CARINA band -> intensity
        if decision.replicas changed: checkpoint, re-mesh, restore (elastic)
        run N steps (failure injection + straggler detection hooks)
        controller.record_unit(...)               # energy/carbon accounting
        checkpoint every K units (async)

    on WorkerFailure: supervisor.on_failure -> ElasticPlan; restore latest
    checkpoint on the (possibly smaller) mesh; resume from step counter.
    The data pipeline is a pure function of step => bit-exact resume.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_step,
                                         restore_checkpoint)
from repro.core.controller import CarinaController, IntensityDecision
from repro.data.pipeline import SyntheticLM
from repro.distributed.fault_tolerance import (FailureInjector, StragglerDetector,
                                               Supervisor, WorkerFailure)
from repro.distributed.sharding import batch_tree_sharding, sharding_tree
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.training.step import init_train_state, make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    steps_per_unit: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every_units: int = 1
    keep: int = 3
    seed: int = 0
    log_every: int = 0


@dataclasses.dataclass
class LoopResult:
    final_step: int
    state: Any
    metrics_history: list
    restarts: int
    straggler_events: int


def _place_state(state, model: Model, mesh):
    if mesh is None:
        return jax.tree.map(jnp.asarray, state)
    shardings = sharding_tree(model.logical_axes(), model.abstract_params(), mesh)
    # opt moments share param shardings; scalars replicated
    from repro.distributed.sharding import replicated
    full = {"params": shardings,
            "opt": {"m": shardings, "v": shardings, "step": replicated(mesh)}}
    if "residuals" in state:
        full["residuals"] = shardings
    return jax.tree.map(lambda a, s: jax.device_put(np.asarray(jax.device_get(a)), s),
                        state, full)


def run_training(model: Model, opt_cfg: AdamWConfig, data: SyntheticLM,
                 loop_cfg: LoopConfig, *,
                 controller: Optional[CarinaController] = None,
                 injector: Optional[FailureInjector] = None,
                 detector: Optional[StragglerDetector] = None,
                 supervisor: Optional[Supervisor] = None,
                 mesh_fn: Optional[Callable[[int], Any]] = None,
                 initial_replicas: int = 1) -> LoopResult:
    supervisor = supervisor or Supervisor()
    detector = detector or StragglerDetector()
    replicas = initial_replicas
    mesh = mesh_fn(replicas) if mesh_fn else None
    ckptr = AsyncCheckpointer(loop_cfg.ckpt_dir, loop_cfg.keep) \
        if loop_cfg.ckpt_dir else None

    # ---- init or restore ---------------------------------------------------
    step = 0
    state = None
    if loop_cfg.ckpt_dir and latest_step(loop_cfg.ckpt_dir) is not None:
        state, meta = _restore(model, opt_cfg, loop_cfg, mesh)
        step = int(meta.get("step", latest_step(loop_cfg.ckpt_dir)))
    if state is None:
        state = init_train_state(model, jax.random.PRNGKey(loop_cfg.seed), opt_cfg)
        state = _place_state(state, model, mesh)

    step_cache: Dict[Any, Any] = {}

    def jitted_for(mesh_):
        key = id(mesh_) if mesh_ is not None else None
        if key not in step_cache:
            fn = make_train_step(model, opt_cfg)
            step_cache[key] = jax.jit(fn, donate_argnums=(0,))
        return step_cache[key]

    metrics_history = []
    unit = 0
    while step < loop_cfg.total_steps:
        decision = (controller.decide() if controller
                    else IntensityDecision("none", 1.0, replicas, 1.0))
        # ---- elastic resize --------------------------------------------------
        if mesh_fn and decision.replicas != replicas and loop_cfg.ckpt_dir:
            ckptr.submit(step, state, {"step": step})
            ckptr.wait()
            replicas = decision.replicas
            mesh = mesh_fn(replicas)
            state, _ = _restore(model, opt_cfg, loop_cfg, mesh)

        t_unit0 = time.monotonic()
        try:
            n = min(loop_cfg.steps_per_unit, loop_cfg.total_steps - step)
            for _ in range(n):
                if injector is not None:
                    injector.check(step)
                batch_np = data.batch_at(step)
                if mesh is not None:
                    sh = batch_tree_sharding(
                        mesh, jax.tree.map(
                            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                            batch_np))
                    batch = jax.tree.map(jax.device_put, batch_np, sh)
                else:
                    batch = jax.tree.map(jnp.asarray, batch_np)
                t0 = time.monotonic()
                if mesh is not None:
                    with mesh:
                        state, metrics = jitted_for(mesh)(state, batch)
                else:
                    state, metrics = jitted_for(mesh)(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.monotonic() - t0
                ev = detector.observe(step, dt)
                if ev is not None and detector.should_exclude(ev) and controller:
                    # straggler exclusion: force a shrink decision next unit
                    controller.max_replicas = max(1, controller.max_replicas - 1)
                step += 1
                if loop_cfg.log_every and step % loop_cfg.log_every == 0:
                    metrics_history.append(
                        {k: float(v) for k, v in metrics.items()} | {"step": step})
            if controller is not None:
                controller.record_unit(decision, steps=n,
                                       runtime_s=time.monotonic() - t_unit0,
                                       meta={"unit": unit})
            unit += 1
            if ckptr and unit % loop_cfg.ckpt_every_units == 0:
                ckptr.submit(step, state, {"step": step})
        except WorkerFailure as e:
            plan = supervisor.on_failure(step, replicas, e)
            if ckptr:
                ckptr.wait()
            replicas = plan.replicas
            mesh = mesh_fn(replicas) if mesh_fn else None
            if loop_cfg.ckpt_dir and latest_step(loop_cfg.ckpt_dir) is not None:
                state, meta = _restore(model, opt_cfg, loop_cfg, mesh)
                step = int(meta.get("step", 0))
            else:  # no checkpoint yet: restart from scratch
                state = init_train_state(model, jax.random.PRNGKey(loop_cfg.seed),
                                         opt_cfg)
                state = _place_state(state, model, mesh)
                step = 0

    if ckptr:
        ckptr.submit(step, state, {"step": step})
        ckptr.wait()
    return LoopResult(step, state, metrics_history, len(supervisor.restarts),
                      len(detector.events))


def _restore(model: Model, opt_cfg: AdamWConfig, loop_cfg: LoopConfig, mesh):
    from repro.training.step import abstract_train_state
    like = abstract_train_state(model, opt_cfg)
    shardings = None
    if mesh is not None:
        from repro.distributed.sharding import replicated
        ps = sharding_tree(model.logical_axes(), model.abstract_params(), mesh)
        shardings = {"params": ps, "opt": {"m": ps, "v": ps,
                                           "step": replicated(mesh)}}
    state, meta = restore_checkpoint(loop_cfg.ckpt_dir, like, shardings=shardings)
    return state, meta
