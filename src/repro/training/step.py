"""Train / serve step factories.

`make_train_step(model, opt_cfg)` -> train_step(state, batch) with:
  * value_and_grad over model.loss (remat policy set in ModelConfig),
  * optional microbatch gradient accumulation (lax.scan over splits),
  * AdamW update (sharded states).
Under pjit, the same function serves 1-device CPU tests and the 512-chip
production mesh — sharding comes entirely from in_shardings.

`make_dp_compressed_step(...)` is the explicit shard_map DP variant with
int8+error-feedback gradient all-reduce (replicated params; <~2B models) —
see distributed/collectives.py.

`make_prefill_step` / `make_decode_step` are the serving lowerings used by
the dry-run's inference cells and the serving engine.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax

from repro.compat import shard_map
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.distributed import collectives as C

F32 = jnp.float32


def init_train_state(model: Model, key, opt_cfg: AdamWConfig) -> Dict[str, Any]:
    params = model.init(key)
    return {"params": params, "opt": init_opt_state(params, opt_cfg)}


def abstract_train_state(model: Model, opt_cfg: AdamWConfig) -> Dict[str, Any]:
    from repro.optim.adamw import abstract_opt_state
    aparams = model.abstract_params()
    return {"params": aparams, "opt": abstract_opt_state(aparams, opt_cfg)}


def _split_microbatches(batch: Dict[str, jax.Array], n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(model: Model, opt_cfg: AdamWConfig, *, grad_accum: int = 1):
    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    def train_step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            from repro.models import layers as _L
            mbs = _split_microbatches(batch, grad_accum)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(F32), g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            if _L.exact_costing():   # unroll: scan bodies undercount in HLO cost
                carry, ms_list = (g0, jnp.zeros((), F32)), []
                for i in range(grad_accum):
                    mb = jax.tree.map(lambda t: t[i], mbs)
                    carry, m = acc_body(carry, mb)
                    ms_list.append(m)
                grads, loss_sum = carry
                ms = jax.tree.map(lambda *ts: jnp.stack(ts), *ms_list)
            else:
                (grads, loss_sum), ms = jax.lax.scan(
                    acc_body, (g0, jnp.zeros((), F32)), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        new_params, new_opt, opt_metrics = adamw_update(params, grads, state["opt"], opt_cfg)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


# ---------------------------------------------------------------------------
def make_dp_compressed_step(model: Model, opt_cfg: AdamWConfig, mesh: Mesh,
                            dp_axis: str = "data"):
    """Explicit shard_map DP with int8+EF compressed gradient all-reduce.
    Params/opt replicated; batch sharded on dp_axis; state carries
    `residuals` (error-feedback buffers)."""

    def local_step(state, batch):
        params = state["params"]

        def loss_fn(p):
            return model.loss(p, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, new_res = C.compressed_psum_grads(grads, state["residuals"], dp_axis)
        loss = jax.lax.pmean(loss, dp_axis)
        metrics = jax.tree.map(lambda x: jax.lax.pmean(x, dp_axis), metrics)
        new_params, new_opt, opt_metrics = adamw_update(params, grads, state["opt"], opt_cfg)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return {"params": new_params, "opt": new_opt, "residuals": new_res}, metrics

    rep = P()

    def step_fn(state, batch):
        in_specs = (jax.tree.map(lambda _: rep, state),
                    jax.tree.map(lambda _: P(dp_axis), batch))
        out_state_spec = jax.tree.map(lambda _: rep, state)
        fn = shard_map(
            local_step, mesh=mesh, in_specs=in_specs,
            out_specs=(out_state_spec,
                       {"nll": rep, "acc": rep, "aux": rep, "lr": rep,
                        "grad_norm": rep, "loss": rep}),
            check_vma=False)
        return fn(state, batch)

    return step_fn


def init_dp_compressed_state(model: Model, key, opt_cfg: AdamWConfig):
    params = model.init(key)
    return {"params": params, "opt": init_opt_state(params, opt_cfg),
            "residuals": C.init_residuals(params)}


# ---------------------------------------------------------------------------
def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens, index):
        return model.decode_step(params, cache, tokens, index)
    return decode_step
