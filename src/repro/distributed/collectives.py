"""Distributed-optimization collectives.

1. `int8_ring_allreduce`: chunked ring reduce-scatter + all-gather in which
   every hop's wire payload is int8 (+ one fp32 scale): ~8x less ICI
   traffic than an fp32 all-reduce, ~4x less than bf16.  Partial sums are
   requantized per hop (1-bit-SGD lineage); `compressed_psum_grads` adds
   sender-side error feedback so quantization error does not bias SGD.
   Used by the shard_map DP train-step variant (training/step.py) for
   replicated-parameter data parallelism — with FSDP/GSPMD the reductions
   are internal to XLA and cannot be intercepted (DESIGN.md §5).

2. `allgather_matmul_overlapped`: chunked all-gather -> matmul pipelining
   via a ppermute ring — each ICI hop's weight chunk is consumed by a
   partial matmul while the next hop is in flight.  A §Perf hillclimb
   option for FSDP all-gathers on the critical path.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size

F32 = jnp.float32
INT8_MAX = 127.0


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / INT8_MAX + 1e-20
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale.astype(F32)


def _deq(q, s):
    return q.astype(F32) * s


def int8_ring_allreduce(x: jax.Array, axis: str) -> jax.Array:
    """Inside shard_map: sum `x` (any shape, fp32) over `axis` with int8 wire
    traffic.  Chunked ring: reduce-scatter (n-1 hops) + all-gather (n-1 hops);
    every hop sends one int8 chunk + fp32 scale."""
    n = axis_size(axis)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis)
    shape = x.shape
    flat = x.reshape(-1).astype(F32)
    c = -(-flat.shape[0] // n)
    flat = jnp.pad(flat, (0, n * c - flat.shape[0]))
    chunks = flat.reshape(n, c)
    right = [(j, (j + 1) % n) for j in range(n)]

    # ---- reduce-scatter: after n-1 steps, rank i owns sum of chunk (i+1)%n
    def rs_step(t, ch):
        send_idx = (idx - t) % n
        q, s = quantize_int8(ch[send_idx])
        q = jax.lax.ppermute(q, axis, right)
        s = jax.lax.ppermute(s, axis, right)
        recv_idx = (idx - t - 1) % n
        return ch.at[recv_idx].add(_deq(q, s))

    chunks = jax.lax.fori_loop(0, n - 1, rs_step, chunks)

    # ---- all-gather of the owned (fully reduced) chunks: each owner
    # quantizes ONCE; the same int8 payload is forwarded around the ring so
    # every rank ends bit-identical (one quantization error in this phase).
    q0, s0 = quantize_int8(chunks[(idx + 1) % n])
    chunks = chunks.at[(idx + 1) % n].set(_deq(q0, s0))

    def ag_step(t, carry):
        ch, q, s = carry
        q = jax.lax.ppermute(q, axis, right)
        s = jax.lax.ppermute(s, axis, right)
        recv_idx = (idx - t) % n
        return ch.at[recv_idx].set(_deq(q, s)), q, s

    chunks, _, _ = jax.lax.fori_loop(0, n - 1, ag_step, (chunks, q0, s0))
    return chunks.reshape(-1)[: _size(shape)].reshape(shape)


def _size(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def compressed_psum_grads(grads, residuals, axis: str):
    """Inside shard_map: mean-all-reduce `grads` over `axis` in int8 with
    sender-side error feedback.  Returns (reduced grads, new residuals)."""
    n = axis_size(axis)

    def one(g, r):
        gf = g.astype(F32) + r
        q, s = quantize_int8(gf)
        contrib = _deq(q, s)
        new_r = gf - contrib                    # error feedback
        tot = int8_ring_allreduce(contrib, axis)
        return (tot / n).astype(g.dtype), new_r

    pairs = jax.tree.map(one, grads, residuals)
    new_g = jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_r


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)


# ---------------------------------------------------------------------------
def allgather_matmul_overlapped(x: jax.Array, w_shard: jax.Array, axis: str):
    """Inside shard_map: y = x @ all_gather(w_shard, axis) with the gather
    pipelined against partial matmuls via a ppermute ring.

    w is sharded on its FIRST (contraction) dim; x: full (m, k) activation;
    w_shard: (k/n, f).  Each step multiplies the chunk currently held while
    the next chunk is in flight.
    """
    n = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    k_shard = w_shard.shape[0]
    left = [(j, (j - 1) % n) for j in range(n)]

    def body(i, carry):
        acc, w_cur = carry
        src = (idx + i) % n
        x_chunk = jax.lax.dynamic_slice_in_dim(x, src * k_shard, k_shard, axis=1)
        acc = acc + jnp.einsum("mk,kf->mf", x_chunk.astype(F32),
                               w_cur.astype(F32))
        w_nxt = jax.lax.ppermute(w_cur, axis, left)
        return acc, w_nxt

    acc = jnp.zeros((x.shape[0], w_shard.shape[1]), F32)
    acc, _ = jax.lax.fori_loop(0, n, body, (acc, w_shard))
    return acc.astype(x.dtype)
