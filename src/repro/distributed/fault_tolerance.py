"""Fault tolerance: failure injection, straggler detection, elastic resize
decisions, and the checkpoint-restart supervisor policy.

At 1000-node scale the failure source is real (XLA halo exchange errors,
preempted VMs, link flaps).  In this container failures are *injected*
(FailureInjector) so the supervisor's restore/resize path is exercised by
tests exactly as it would run in production: training/loop.py catches
WorkerFailure, restores the latest atomic checkpoint, optionally shrinks the
dp width (elastic), and resumes from the step counter — the data pipeline
being a pure function of step makes the resume bit-exact.

Straggler mitigation: per-step wall times feed an EMA; a step slower than
`threshold x median` marks a straggler event; `policy="exclude"` triggers an
elastic resize that drops the slow replica (on real fleets: reschedule the
host), `policy="log"` only records (the CARINA dashboard shows the events).
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
import time
from typing import Callable, Deque, List, Optional


class WorkerFailure(RuntimeError):
    """A (simulated or real) replica failure during a step."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule: fail at the given global steps."""
    fail_at_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise WorkerFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    median: float


class StragglerDetector:
    def __init__(self, window: int = 32, threshold: float = 2.0,
                 policy: str = "log"):
        self.window: Deque[float] = collections.deque(maxlen=window)
        self.threshold = threshold
        self.policy = policy
        self.events: List[StragglerEvent] = []

    def observe(self, step: int, step_time: float) -> Optional[StragglerEvent]:
        ev = None
        if len(self.window) >= 8:
            med = statistics.median(self.window)
            if step_time > self.threshold * med:
                ev = StragglerEvent(step, step_time, med)
                self.events.append(ev)
        self.window.append(step_time)
        return ev

    def should_exclude(self, ev: Optional[StragglerEvent]) -> bool:
        return ev is not None and self.policy == "exclude"


@dataclasses.dataclass
class ElasticPlan:
    """Resize decision: new dp width (replicas) after a failure/straggler."""
    replicas: int
    reason: str


class Supervisor:
    """Checkpoint-restart supervision state machine (driven by training/loop).

    Tracks restarts, computes the post-failure elastic plan, and enforces a
    restart budget (gives up after `max_restarts` so a crash-looping fleet
    pages a human instead of burning CO2 — CARINA would notice)."""

    def __init__(self, max_restarts: int = 8, elastic: bool = True,
                 min_replicas: int = 1):
        self.max_restarts = max_restarts
        self.elastic = elastic
        self.min_replicas = min_replicas
        self.restarts: List[dict] = []

    def on_failure(self, step: int, replicas: int, exc: Exception) -> ElasticPlan:
        if len(self.restarts) >= self.max_restarts:
            raise RuntimeError(
                f"restart budget exhausted ({self.max_restarts})") from exc
        if self.elastic and replicas > self.min_replicas:
            new_replicas = max(self.min_replicas, replicas // 2)
            reason = f"failure at step {step}: shrink {replicas}->{new_replicas}"
        else:
            new_replicas = replicas
            reason = f"failure at step {step}: restart at same width"
        self.restarts.append({"step": step, "replicas": new_replicas,
                              "reason": reason, "error": repr(exc),
                              "time": time.time()})
        return ElasticPlan(new_replicas, reason)
