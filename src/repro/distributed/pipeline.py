"""GPipe-style pipeline parallelism over a "pipe" mesh axis.

For meshes that dedicate an axis to pipeline stages (an alternative to the
production 2-axis mesh — e.g. (pipe=4, data=8, model=8) on odd-shaped
fleets), layers are split into `P` contiguous stages; `M` microbatches flow
through a ppermute ring with the classic GPipe schedule (M + P - 1 ticks,
bubble fraction (P-1)/(M+P-1)).

Implementation: jax.shard_map over the "pipe" axis; each device holds its
stage's layer parameters (stacked dim 0 sharded over "pipe") and runs
`stage_fn` every tick; activations hop stages via collective-permute.
Forward-only ticks are jit-traceable (static loop, M and P are config);
the whole pipeline is differentiable (ppermute has a transpose rule), so
training works through it.

    y = pipeline_apply(mesh, stage_fn, stage_params, x, n_micro=M)

Contract: x: (B, ...) with B % M == 0; stage_params leaves stacked (P, ...);
stage_fn(stage_param_slice, micro_x) -> micro_y with y.shape == x.shape
(uniform width across stages, as in a decoder LM).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax

from repro.compat import shard_map
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(mesh: Mesh, stage_fn: Callable, stage_params: Any,
                   x: jax.Array, n_micro: int, axis: str = "pipe") -> jax.Array:
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro
    micro = x.reshape((n_micro, mb) + x.shape[1:])

    def per_stage(params, micro_in):
        # params: this stage's slice (leaves had leading dim P, now sliced)
        params = jax.tree.map(lambda t: t[0], params)
        idx = jax.lax.axis_index(axis)
        ticks = n_micro + n_stages - 1
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        carry = jnp.zeros((mb,) + x.shape[1:], x.dtype)
        out = jnp.zeros_like(micro_in[0])
        outs = jnp.zeros((n_micro, mb) + x.shape[1:], x.dtype)
        for t in range(ticks):
            # stage 0 ingests microbatch t (if any); others take the hop
            feed = micro_in[min(t, n_micro - 1)]
            inp = jnp.where(idx == 0,
                            feed if t < n_micro else jnp.zeros_like(feed),
                            carry)
            out = stage_fn(params, inp)
            # last stage emits microbatch (t - (P-1)) at tick t
            emit_i = t - (n_stages - 1)
            if emit_i >= 0:
                outs = jax.lax.cond(
                    idx == n_stages - 1,
                    lambda o: o.at[emit_i].set(out),
                    lambda o: o, outs)
            carry = jax.lax.ppermute(out, axis, fwd)
        # only the last stage's buffer is meaningful; broadcast it to every
        # stage via a masked psum so the caller sees a replicated result
        outs = jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs))
        outs = jax.lax.psum(outs, axis)
        return outs[None]

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params), P())
    out_specs = P(axis)
    y = shard_map(per_stage, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_vma=False)(
        jax.tree.map(lambda t: t, stage_params), micro)
    # out dim0 = n_stages (one copy per stage); take the replicated copy
    y = y[0] if n_stages == 1 else y[0]
    return y.reshape((b,) + x.shape[1:])
