"""Logical-axis sharding rules -> NamedSharding trees.

Model code declares per-dim *logical* axes on every ParamSpec
("embed", "heads", "mlp", "experts", "vocab", "batch", ...).  This module
maps them onto the production mesh:

    TP/EP axes ("heads","mlp","experts","vocab","ssm_inner","rnn",...)  -> "model"
    FSDP axis  ("embed")                                   -> ("pod","data")
    DP axis    ("batch")                                   -> ("pod","data")

XLA requires evenly divisible shardings for jit arguments, so resolution is
per-array: any dim whose size is not divisible by the assigned mesh-axis
product falls back to replication (None).  This is how MQA KV projections
(kv_heads=1) and qwen2.5's 40 q-heads on a 16-way model axis are handled —
recorded per-arch in the roofline notes (DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# logical axis -> role
TP_AXES = ("heads", "kv_heads", "mlp", "experts", "vocab", "ssm_inner", "rnn",
           "kv_seq")
FSDP_AXES = ("embed",)
DP_AXES = ("batch",)


def dp_axis_names(mesh: Mesh) -> Tuple[str, ...]:
    names = [n for n in ("pod", "data") if n in mesh.axis_names]
    return tuple(names)


def tp_axis_name(mesh: Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def axis_size(mesh: Mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    return int(np.prod([mesh.shape[n] for n in names])) if names else 1


def resolve_pspec(shape: Sequence[int], axes: Sequence[Optional[str]],
                  mesh: Mesh, *, fsdp: bool = True, tp: bool = True,
                  overrides: Optional[Dict[str, Any]] = None) -> P:
    """Per-dim resolution with divisibility fallback to replication.
    `overrides` maps a logical axis name directly to mesh axes (tuple/str/
    None) — used by e.g. the 2D-TP decode plan ("kv_seq" -> (model, data),
    "batch" -> None)."""
    dp = dp_axis_names(mesh)
    tpa = tp_axis_name(mesh)
    spec = []
    used: set = set()
    for dim, ax in zip(shape, axes):
        assign: Any = None
        if overrides is not None and ax in overrides:
            cand = overrides[ax]
            cand_t = (cand,) if isinstance(cand, str) else tuple(cand or ())
            if cand_t and not (set(cand_t) & used):
                assign = cand if isinstance(cand, str) else cand_t
        elif ax in TP_AXES and tp and tpa and tpa not in used:
            assign = tpa
        elif ax in FSDP_AXES and fsdp and dp and not (set(dp) & used):
            assign = dp if len(dp) > 1 else dp[0]
        elif ax in DP_AXES and dp and not (set(dp) & used):
            assign = dp if len(dp) > 1 else dp[0]
        if assign is not None and dim % axis_size(mesh, assign) != 0:
            assign = None
        if assign is not None:
            used.update([assign] if isinstance(assign, str) else assign)
        spec.append(assign)
    # trim trailing Nones
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def sharding_tree(logical_tree, shape_tree, mesh: Mesh, *, fsdp: bool = True,
                  tp: bool = True, overrides: Optional[Dict[str, Any]] = None):
    """logical_tree: tree of per-dim axis tuples; shape_tree: matching tree of
    ShapeDtypeStructs (or arrays).  Returns tree of NamedSharding."""
    def one(axes, sds):
        return NamedSharding(mesh, resolve_pspec(sds.shape, axes, mesh,
                                                 fsdp=fsdp, tp=tp,
                                                 overrides=overrides))
    # logical axes leaves are tuples — match against shape tree structure
    return jax.tree.map(one, logical_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def batch_sharding(mesh: Mesh, batch_size: int) -> NamedSharding:
    dp = dp_axis_names(mesh)
    if dp and batch_size % axis_size(mesh, dp) == 0:
        return NamedSharding(mesh, P(dp if len(dp) > 1 else dp[0]))
    return NamedSharding(mesh, P())


def batch_tree_sharding(mesh: Mesh, batch_tree):
    """Shard dim 0 (batch) of every leaf in an input batch dict."""
    def one(sds):
        return batch_sharding(mesh, sds.shape[0])
    return jax.tree.map(one, batch_tree)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
