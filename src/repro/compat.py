"""Cross-version jax API aliases.

`shard_map` moved from `jax.experimental.shard_map` to the jax namespace
and renamed its replication-check kwarg (`check_rep` -> `check_vma`).
Import it from here with the new-style `check_vma` spelling and it works
on both sides of the move.  `axis_size` appeared in jax.lax later than
`axis_index`; the fallback is the standard psum-of-ones identity.
`enable_x64` is the double-precision context manager; implemented here
over the config flag with an explicit frame stack so nested and
out-of-order exits restore the right value on every jax version.
"""
from __future__ import annotations

import contextlib
import threading

import jax


class _X64Frames(threading.local):
    """Per-thread stack of live `enable_x64` frames."""

    def __init__(self):
        self.stack = []  # list of [token, saved_value]


_X64 = _X64Frames()


@contextlib.contextmanager
def enable_x64(new_val: bool = True):
    """Set the `jax_enable_x64` flag for the duration of the context.

    Unlike a naive save/restore over the global config (the old
    fallback), each frame is tracked on a stack so the manager is
    reentrancy-safe: nested contexts restore the value their *own*
    entry observed, and an inner frame closed out of order (e.g. a
    generator finalized while a newer context is active) hands its
    saved value to the frame above it instead of clobbering the live
    setting.  This became load-bearing once the per-plan dtype policy
    made the engine open fp64 contexts inside callers' own contexts.
    """
    token = object()
    stack = _X64.stack
    stack.append([token, bool(jax.config.jax_enable_x64)])
    jax.config.update("jax_enable_x64", bool(new_val))
    try:
        yield
    finally:
        for i in range(len(stack) - 1, -1, -1):
            if stack[i][0] is token:
                saved = stack[i][1]
                del stack[i]
                if i < len(stack):
                    # Out-of-order exit: a newer frame is still active.
                    # Leave the flag as that frame set it, but make the
                    # newer frame restore *our* saved value when it
                    # exits (it captured the value we had installed).
                    stack[i][1] = saved
                else:
                    jax.config.update("jax_enable_x64", saved)
                break


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(axis_name):
        return jax.lax.psum(1, axis_name)

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        if check_vma is not None:
            kw["check_rep"] = check_vma
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
