"""Cross-version jax API aliases.

`shard_map` moved from `jax.experimental.shard_map` to the jax namespace
and renamed its replication-check kwarg (`check_rep` -> `check_vma`).
Import it from here with the new-style `check_vma` spelling and it works
on both sides of the move.  `axis_size` appeared in jax.lax later than
`axis_index`; the fallback is the standard psum-of-ones identity.
`enable_x64` is the double-precision context manager; implemented here
over the config flag with an explicit frame stack so nested and
out-of-order exits restore the right value on every jax version.
"""
from __future__ import annotations

import contextlib
import os
import threading

import jax


class _X64Frames(threading.local):
    """Per-thread stack of live `enable_x64` frames."""

    def __init__(self):
        self.stack = []  # list of [token, saved_value]


_X64 = _X64Frames()


@contextlib.contextmanager
def enable_x64(new_val: bool = True):
    """Set the `jax_enable_x64` flag for the duration of the context.

    Unlike a naive save/restore over the global config (the old
    fallback), each frame is tracked on a stack so the manager is
    reentrancy-safe: nested contexts restore the value their *own*
    entry observed, and an inner frame closed out of order (e.g. a
    generator finalized while a newer context is active) hands its
    saved value to the frame above it instead of clobbering the live
    setting.  This became load-bearing once the per-plan dtype policy
    made the engine open fp64 contexts inside callers' own contexts.
    """
    token = object()
    stack = _X64.stack
    stack.append([token, bool(jax.config.jax_enable_x64)])
    jax.config.update("jax_enable_x64", bool(new_val))
    try:
        yield
    finally:
        for i in range(len(stack) - 1, -1, -1):
            if stack[i][0] is token:
                saved = stack[i][1]
                del stack[i]
                if i < len(stack):
                    # Out-of-order exit: a newer frame is still active.
                    # Leave the flag as that frame set it, but make the
                    # newer frame restore *our* saved value when it
                    # exits (it captured the value we had installed).
                    stack[i][1] = saved
                else:
                    jax.config.update("jax_enable_x64", saved)
                break


# Active persistent-compilation-cache directory (None = not enabled).
_compilation_cache_dir = None


def enable_persistent_compilation_cache(cache_dir=None):
    """Point jax's persistent compilation cache at a directory.

    The plan cache (core/plancache.py) removes re-*staging* across
    processes but a fresh process still pays every XLA compile; jax's
    own persistent cache closes that gap.  `CARINA_JAX_CACHE` (env)
    wins over `cache_dir`; with neither set this is a no-op returning
    None.  The min-entry-size/min-compile-time floors are dropped so
    even the engine's small chunk kernels are cached — CARINA's
    kernels are many and cheap, which is exactly the population the
    default floors exclude.  Idempotent (re-pointing at the active
    directory is free) and soft-failing: a jax too old to have the
    config knobs just leaves the cache off.
    """
    global _compilation_cache_dir
    target = os.environ.get("CARINA_JAX_CACHE") or cache_dir
    if not target:
        return None
    target = os.path.abspath(target)
    if _compilation_cache_dir == target:
        return target
    try:
        os.makedirs(target, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", target)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except Exception:
        return None
    _compilation_cache_dir = target
    return target


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(axis_name):
        return jax.lax.psum(1, axis_name)

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        if check_vma is not None:
            kw["check_rep"] = check_vma
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
