"""Cross-version jax API aliases.

`shard_map` moved from `jax.experimental.shard_map` to the jax namespace
and renamed its replication-check kwarg (`check_rep` -> `check_vma`).
Import it from here with the new-style `check_vma` spelling and it works
on both sides of the move.  `axis_size` appeared in jax.lax later than
`axis_index`; the fallback is the standard psum-of-ones identity.
`enable_x64` is the double-precision context manager from
jax.experimental, re-implemented over the config flag where absent.
"""
from __future__ import annotations

import contextlib

import jax

try:
    from jax.experimental import enable_x64  # noqa: F401
except ImportError:                           # pragma: no cover - new jax
    @contextlib.contextmanager
    def enable_x64(new_val: bool = True):
        old = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", new_val)
        try:
            yield
        finally:
            jax.config.update("jax_enable_x64", old)

if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(axis_name):
        return jax.lax.psum(1, axis_name)

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        if check_vma is not None:
            kw["check_rep"] = check_vma
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
