"""Aggregate dry-run JSONs into the EXPERIMENTS.md §Dry-run / §Roofline
tables (markdown to stdout).

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCH_NAMES, SHAPES

V5E_HBM = 16e9  # bytes per chip


def fmt_bytes(n):
    if n is None:
        return "-"
    for u in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{u}"
        n /= 1024
    return f"{n:.1f}PB"


def fmt_f(x, nd=3):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    if abs(x) < 0.001:
        return f"{x:.1e}"
    return f"{x:.{nd}f}"


def load(dirname):
    recs = {}
    for f in glob.glob(os.path.join(dirname, "*.json")):
        try:
            r = json.load(open(f))
        except Exception:
            continue
        if "arch" in r:
            recs[(r["arch"], r["shape"], r.get("mesh",
                  "pod2x16x16" if r.get("multi_pod") else "pod16x16"))] = r
    return recs


def dryrun_table(recs, mesh):
    lines = [
        f"### Mesh {mesh}",
        "",
        "| arch | shape | status | compile (s) | params | temp/chip | args/chip "
        "| HLO GFLOP/chip | HLO GB/chip | coll GB/chip | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            r = recs.get((arch, shape, mesh))
            if r is None:
                lines.append(f"| {arch} | {shape} | MISSING | | | | | | | |")
                continue
            if r["status"] != "ok":
                reason = r.get("reason", r.get("error", ""))[:60]
                lines.append(f"| {arch} | {shape} | {r['status']} "
                             f"({reason}) | | | | | | | |")
                continue
            m = r["memory_analysis"]
            pc = r["per_chip"]
            temp = m.get("temp_size_in_bytes")
            args = m.get("argument_size_in_bytes")
            fits = "yes" if (temp or 0) + (args or 0) < V5E_HBM else "NO"
            lines.append(
                f"| {arch} | {shape} | ok | {r.get('compile_s', '-')} "
                f"| {r['params']/1e9:.2f}B | {fmt_bytes(temp)} | {fmt_bytes(args)} "
                f"| {pc['hlo_flops']/1e9:.0f} | {pc['hlo_bytes']/1e9:.0f} "
                f"| {pc['collective_bytes']/1e9:.2f} | {fits} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | bottleneck "
        "| MODEL_FLOPS | useful ratio | step (s) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            r = recs.get((arch, shape, "pod16x16"))
            if r is None or r["status"] != "ok":
                status = "missing" if r is None else r["status"]
                if status == "skipped":
                    lines.append(f"| {arch} | {shape} | skipped | | | | | | |")
                else:
                    lines.append(f"| {arch} | {shape} | {status} | | | | | | |")
                continue
            ro = r["roofline"]
            lines.append(
                f"| {arch} | {shape} | {fmt_f(ro['compute_s'])} "
                f"| {fmt_f(ro['memory_s'])} | {fmt_f(ro['collective_s'])} "
                f"| **{ro['bottleneck']}** | {ro['model_flops_global']:.2e} "
                f"| {ro['useful_flops_ratio']:.2f} | {fmt_f(ro['step_seconds'])} |")
    return "\n".join(lines)


def summarize(recs):
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    skip = sum(1 for r in recs.values() if r["status"] == "skipped")
    bad = sum(1 for r in recs.values() if r["status"] not in ("ok", "skipped"))
    return f"{ok} ok, {skip} skipped (documented), {bad} failed, of {len(recs)}"


def pod_scaling_table(recs):
    """Weak-scaling 256 -> 512 chips at fixed global work: ideal per-chip
    step time halves (efficiency 1.0 = step256 / (2 * step512))."""
    lines = [
        "| arch | shape | step 256c (s) | step 512c (s) | scaling eff. "
        "| coll/chip ratio |",
        "|---|---|---|---|---|---|",
    ]
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            a = recs.get((arch, shape, "pod16x16"))
            b = recs.get((arch, shape, "pod2x16x16"))
            if not a or not b or a["status"] != "ok" or b["status"] != "ok":
                continue
            s1 = a["roofline"]["step_seconds"]
            s2 = b["roofline"]["step_seconds"]
            eff = s1 / (2.0 * s2) if s2 else 0.0
            c1 = a["per_chip"]["collective_bytes"] or 1.0
            c2 = b["per_chip"]["collective_bytes"]
            lines.append(f"| {arch} | {shape} | {fmt_f(s1)} | {fmt_f(s2)} "
                         f"| {eff:.2f} | {c2 / c1:.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    print(f"## Dry-run summary: {summarize(recs)}\n")
    print(dryrun_table(recs, "pod16x16"))
    print()
    print(dryrun_table(recs, "pod2x16x16"))
    print()
    print("## Roofline (single-pod 16x16, per-chip terms)\n")
    print(roofline_table(recs))
    print()
    print("## Pod scaling (256 -> 512 chips, fixed global work)\n")
    print(pod_scaling_table(recs))


if __name__ == "__main__":
    main()
