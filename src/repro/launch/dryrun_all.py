"""Run every (arch x shape x mesh) dry-run cell in subprocesses (the 512
host-device XLA_FLAGS must be set per-process before jax import, and
compile state must not accumulate).  Caches JSON per cell; re-runs only
missing/failed cells.  Usage:
    PYTHONPATH=src python -m repro.launch.dryrun_all [--out experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ARCH_NAMES, SHAPES, cell_is_applicable, get_config


def cell_list():
    cells = []
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            for multi_pod in (False, True):
                cells.append((arch, shape, multi_pod))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default=None, help="substring filter arch:shape")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cells = cell_list()
    # single-pod first (roofline table), then multi-pod
    cells.sort(key=lambda c: (c[2], c[0], c[1]))
    t_start = time.time()
    for i, (arch, shape, mp) in enumerate(cells):
        mesh = "pod2x16x16" if mp else "pod16x16"
        name = f"{arch}.{shape}.{mesh}"
        if args.only and args.only not in name:
            continue
        path = os.path.join(args.out, name + ".json")
        if os.path.exists(path) and not args.force:
            try:
                with open(path) as f:
                    rec = json.load(f)
                if rec.get("status") in ("ok", "skipped"):
                    print(f"[{i+1}/{len(cells)}] {name}: cached {rec['status']}")
                    continue
            except Exception:
                pass
        cfg = get_config(arch)
        ok, reason = cell_is_applicable(cfg, SHAPES[shape])
        if not ok:
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "multi_pod": mp,
                           "mesh": mesh, "status": "skipped",
                           "reason": reason}, f, indent=2)
            print(f"[{i+1}/{len(cells)}] {name}: skipped ({reason[:60]})")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--out", path]
        if mp:
            cmd.append("--multi-pod")
        t0 = time.time()
        print(f"[{i+1}/{len(cells)}] {name}: compiling ...", flush=True)
        try:
            p = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env=dict(os.environ, PYTHONPATH="src"))
            if p.returncode != 0:
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "multi_pod": mp,
                               "mesh": mesh, "status": "error",
                               "error": (p.stderr or p.stdout)[-1500:]},
                              f, indent=2)
        except subprocess.TimeoutExpired:
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "multi_pod": mp,
                           "mesh": mesh, "status": "timeout"}, f, indent=2)
        with open(path) as f:
            rec = json.load(f)
        dt = time.time() - t0
        extra = ""
        if rec.get("status") == "ok":
            r = rec["roofline"]
            extra = (f" bottleneck={r['bottleneck']}"
                     f" step={r['step_seconds']:.3f}s"
                     f" useful={r['useful_flops_ratio']:.2f}")
        print(f"[{i+1}/{len(cells)}] {name}: {rec.get('status')} "
              f"({dt:.0f}s){extra}", flush=True)
    print(f"total {time.time()-t_start:.0f}s")


if __name__ == "__main__":
    main()
