import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell
on 512 placeholder host devices, and extract the roofline terms.

The two lines above MUST run before any jax import (jax locks the device
count at first init) — which is why this module sets XLA_FLAGS at the very
top and why smoke tests/benches never import it.

Per cell:
    lowered  = jit(step, in_shardings=..., out_shardings=...).lower(*specs)
    compiled = lowered.compile()
    memory_analysis / cost_analysis            -> bytes, FLOPs
    parse compiled HLO for collective bytes    -> all-gather/all-reduce/
                                                  reduce-scatter/all-to-all/
                                                  collective-permute operand sums
Everything is ShapeDtypeStruct-driven: no array is ever materialized.
Results are written as JSON (one file per cell) for benchmarks/roofline.
"""
import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cell_is_applicable, get_config
from repro.configs.base import ModelConfig, ShapeConfig, model_flops
from repro.distributed.sharding import (batch_tree_sharding, replicated,
                                        sharding_tree)
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.models import param as PRM
from repro.optim.adamw import AdamWConfig
from repro.training.step import abstract_train_state, make_train_step

# v5e-class constants (assignment)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if shape.kind in ("train", "prefill"):
        if cfg.encdec:
            return {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), bf16),
                    "tokens": jax.ShapeDtypeStruct((b, cfg.dec_train_len), i32)}
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            out["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_vision_tokens, cfg.d_model), bf16)
        return out
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}


def collective_bytes_from_hlo(hlo: str) -> Dict[str, float]:
    """Sum operand bytes of collective ops in compiled (post-SPMD) HLO."""
    dt_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "c64": 8}
    totals = {k: 0.0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    # lines like:  %all-gather.3 = bf16[4,128,512]{...} all-gather(...)
    pat = re.compile(r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
    for line in hlo.splitlines():
        op = None
        for c in COLLECTIVE_OPS:
            if f" {c}(" in line or f" {c}-start(" in line:
                op = c
                break
        if op is None:
            continue
        m = pat.search(line)
        if not m:
            continue
        dt, dims = m.group(1), m.group(2)
        size = dt_bytes.get(dt, 2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        totals[op] += n * size
        counts[op] += 1
    out = {f"{k}_bytes": v for k, v in totals.items()}
    out.update({f"{k}_count": counts[k] for k in COLLECTIVE_OPS})
    out["collective_bytes"] = sum(totals.values())
    return out


def _cost_get(ca: dict, key: str) -> float:
    try:
        return float(ca.get(key, 0.0) or 0.0)
    except Exception:
        return 0.0


# ---------------------------------------------------------------------------
def _lower_and_compile(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """One AOT lower+compile of (cfg, shape) on mesh. Returns (compiled, t_lower,
    t_compile)."""
    from repro.models import layers as L
    L.set_activation_sharding(mesh, sp=bool(int(os.environ.get("REPRO_SP", "0"))))
    model = build_model(cfg)
    specs = input_specs(cfg, shape)
    t0 = time.time()
    grad_accum = int(os.environ.get("REPRO_GRAD_ACCUM", "1"))
    with mesh:
        if shape.kind == "train":
            opt_cfg = AdamWConfig(state_dtype="bfloat16")
            state = abstract_train_state(model, opt_cfg)
            pshard = sharding_tree(model.logical_axes(), model.abstract_params(),
                                   mesh)
            state_shard = {"params": pshard,
                           "opt": {"m": pshard, "v": pshard,
                                   "step": replicated(mesh)}}
            bshard = batch_tree_sharding(mesh, specs)
            step_fn = make_train_step(model, opt_cfg, grad_accum=grad_accum)
            jitted = jax.jit(step_fn, in_shardings=(state_shard, bshard),
                             out_shardings=(state_shard, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, specs)
        elif shape.kind == "prefill":
            pshard = sharding_tree(model.logical_axes(), model.abstract_params(),
                                   mesh)
            bshard = batch_tree_sharding(mesh, specs)
            jitted = jax.jit(model.prefill, in_shardings=(pshard, bshard))
            lowered = jitted.lower(model.abstract_params(), specs)
        else:  # decode / long_decode: serve_step against a seq_len cache
            cache_over = None
            if cfg.decode_2d_tp:
                # 2D TP decode plan: weights sharded (model x data) as usual,
                # batch REPLICATED (no dim competes with "data"), cache seq
                # sharded over both axes -> GSPMD emits tiny activation psums
                # instead of per-layer FSDP weight all-gathers.
                dpn = [a for a in ("pod", "data") if a in mesh.axis_names]
                cache_over = {"kv_seq": ("model",) + tuple(dpn), "batch": None}
                # residual stream feature-sharded over "data" => activation
                # psums (4 MB) instead of weight all-gathers (GB)
                L.set_activation_sharding(mesh, mode="feature")
            pshard = sharding_tree(model.logical_axes(), model.abstract_params(),
                                   mesh)
            cache = model.cache_abstract(shape.global_batch, shape.seq_len)
            cshard = sharding_tree(model.cache_logical_axes(
                shape.global_batch, shape.seq_len), cache, mesh,
                overrides=cache_over)
            bshard = batch_tree_sharding(mesh, specs) if not cfg.decode_2d_tp \
                else jax.tree.map(lambda _: replicated(mesh), specs)
            idx = jax.ShapeDtypeStruct((), jnp.int32)

            def serve_step(params, cache, tokens, index):
                return model.decode_step(params, cache, tokens, index)

            jitted = jax.jit(serve_step,
                             in_shardings=(pshard, cshard, bshard["tokens"],
                                           replicated(mesh)),
                             out_shardings=(None, cshard),
                             donate_argnums=(1,))
            lowered = jitted.lower(model.abstract_params(), cache,
                                   specs["tokens"], idx)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, t_lower, t_compile


def _extract_costs(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    flops = _cost_get(ca, "flops")
    hbm_bytes = _cost_get(ca, "bytes accessed")
    if hbm_bytes == 0.0:
        hbm_bytes = sum(v for k, v in ca.items()
                        if isinstance(v, (int, float)) and "bytes accessed" in k)
    return {"hlo_flops": flops, "hlo_bytes": hbm_bytes, **coll}


def _depth_pair(cfg: ModelConfig):
    """Two shallow UNROLLED variants for per-layer cost differencing, plus
    unit counts (u1, u2, u_full).  XLA cost analysis counts a scanned loop
    body ONCE regardless of trip count, so per-layer costs must come from
    unrolled shallow compiles and linear extrapolation (EXPERIMENTS.md
    §Dry-run, methodology note)."""
    plen = len(cfg.block_pattern)
    if cfg.encdec:
        mk = lambda L: dataclasses.replace(cfg, num_layers=L, enc_layers=L,
                                           dec_layers=L, use_scan=False)
        return mk(2), mk(4), 2, 4, cfg.enc_layers
    if cfg.moe is not None and cfg.moe.layer_mode == "all_but_first":
        mk = lambda L: dataclasses.replace(cfg, num_layers=1 + L, use_scan=False)
        return mk(2), mk(4), 2, 4, cfg.num_layers - 1
    if plen > 1:
        # pattern units (e.g. recurrentgemma (r,r,local)); tail counted
        # fractionally
        mk = lambda U: dataclasses.replace(cfg, num_layers=U * plen,
                                           use_scan=False)
        u_full = cfg.num_layers / plen
        return mk(2), mk(4), 2, 4, u_full
    mk = lambda L: dataclasses.replace(cfg, num_layers=L, use_scan=False)
    return mk(2), mk(4), 2, 4, cfg.num_layers


def _extrapolate(c1: Dict[str, float], c2: Dict[str, float],
                 u1: float, u2: float, u_full: float) -> Dict[str, float]:
    out = {}
    for k in c1:
        slope = (c2[k] - c1[k]) / (u2 - u1)
        out[k] = c1[k] + (u_full - u1) * slope
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: Optional[dict] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    base_kw = dict(remat="full", use_scan=True)
    base_kw.update(overrides or {})
    cfg = dataclasses.replace(cfg, **base_kw)
    shape = SHAPES[shape_name]
    ok, reason = cell_is_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))

    # 1) full scanned compile: proves the cell compiles; peak-memory analysis
    compiled_full, t_lower, t_compile = _lower_and_compile(cfg, shape, mesh)
    mem = compiled_full.memory_analysis()
    mem_dict = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        mem_dict[attr] = getattr(mem, attr, None)
    full_costs_scanned = _extract_costs(compiled_full)
    del compiled_full

    # 2) depth-differenced costs from UNROLLED shallow compiles in
    #    exact-costing mode (scan bodies are undercounted by cost analysis)
    from repro.models import layers as L
    cfg1, cfg2, u1, u2, u_full = _depth_pair(cfg)
    L.set_costing_mode(True)
    try:
        comp1, _, t_c1 = _lower_and_compile(cfg1, shape, mesh)
        c1 = _extract_costs(comp1)
        del comp1
        comp2, _, t_c2 = _lower_and_compile(cfg2, shape, mesh)
        c2 = _extract_costs(comp2)
        del comp2
    finally:
        L.set_costing_mode(False)
    costs = _extrapolate(c1, c2, u1, u2, u_full)

    flops = costs["hlo_flops"]
    hbm_bytes = costs["hlo_bytes"]
    coll_bytes = costs["collective_bytes"]

    compute_s = flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    collective_s = coll_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get).replace("_s", "")

    mf = model_flops(cfg, shape)          # MODEL_FLOPS global
    hlo_flops_global = flops * chips
    useful = mf / hlo_flops_global if hlo_flops_global else 0.0

    model = build_model(cfg)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "multi_pod": multi_pod, "chips": chips, "status": "ok",
        "params": model.param_count(),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "compile_shallow_s": round(t_c1 + t_c2, 1),
        "per_chip": {
            "hlo_flops": flops, "hlo_bytes": hbm_bytes,
            "collective_bytes": coll_bytes,
        },
        "per_chip_scanned_raw": full_costs_scanned,
        "collectives": {k: costs.get(k) for k in costs if k != "hlo_flops"
                        and k != "hlo_bytes"},
        "memory_analysis": mem_dict,
        "roofline": {
            **terms,
            "bottleneck": bottleneck,
            "model_flops_global": mf,
            "useful_flops_ratio": useful,
            "step_seconds": max(terms.values()),
        },
        "overrides": overrides or {},
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--overrides", default=None,
                    help="JSON dict of ModelConfig overrides (hillclimbing)")
    args = ap.parse_args()
    overrides = json.loads(args.overrides) if args.overrides else None
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod, overrides)
    except Exception as e:  # report failures as data, not crashes
        import traceback
        rec = {"arch": args.arch, "shape": args.shape,
               "multi_pod": args.multi_pod, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    js = json.dumps(rec, indent=2, default=float)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(js)
    print(js if rec.get("status") != "ok" else
          json.dumps({k: rec[k] for k in
                      ("arch", "shape", "mesh", "status", "compile_s",
                       "roofline")}, indent=2, default=float))
    if rec.get("status") == "ok":
        print("memory_analysis:", rec["memory_analysis"])
        print("cost_analysis per chip:", rec["per_chip"])


if __name__ == "__main__":
    main()
