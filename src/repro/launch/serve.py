"""Production serving entry point (see examples/serving.py for the tour).

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.core import CarinaController, RunTracker, SimClock
from repro.models import build_model
from repro.models import layers as L
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    L.set_kernel_mode("auto")
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tracker = RunTracker(f"serve-{cfg.name}")
    engine = ServingEngine(model, params, slots=args.slots, s_max=args.s_max,
                           controller=CarinaController(
                               tracker=tracker, max_replicas=1,
                               clock=SimClock(start_hour=12.0)))
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        engine.submit(rng.integers(0, cfg.vocab_size,
                                   size=rng.integers(4, 16)).astype(np.int32),
                      max_new=args.max_new)
    done = engine.run_until_drained()
    s = tracker.close()
    print(f"completed {len(done)} requests; energy {s.energy_kwh*1e3:.3f} Wh; "
          f"CO2e {s.co2_kg*1e3:.3f} g")


if __name__ == "__main__":
    main()
