"""Production training entry point.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 100 --policy peak_aware_boosted_offhours [--smoke]

On a real TPU fleet this binary runs per host (jax.distributed.initialize);
here it sizes itself to the local device count.  Selects the Pallas kernel
path automatically on TPU backends.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

import repro.carina as carina
from repro.configs import ARCH_NAMES, get_config
from repro.core import POLICIES, SimClock
from repro.data.pipeline import SyntheticLM
from repro.distributed.fault_tolerance import Supervisor
from repro.launch.mesh import make_mesh_for
from repro.models import build_model
from repro.models import layers as L
from repro.optim.adamw import AdamWConfig
from repro.training.loop import LoopConfig, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--policy", default="baseline", choices=list(POLICIES))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--blocked-xent", action="store_true")
    args = ap.parse_args()

    L.set_kernel_mode("auto")      # pallas on TPU, XLA elsewhere
    cfg = get_config(args.arch, smoke=args.smoke)
    cfg = dataclasses.replace(cfg, remat=args.remat,
                              blocked_xent=args.blocked_xent)
    model = build_model(cfg)
    n_dev = len(jax.devices())
    print(f"devices={n_dev} arch={cfg.name} params={model.param_count():,}")

    def mesh_fn(replicas):
        m = make_mesh_for(replicas)
        L.set_activation_sharding(m)
        return m

    opt = AdamWConfig(total_steps=args.steps,
                      warmup_steps=max(1, args.steps // 10))
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq)
    # Algorithm 1 line 3: detect machine characteristics, initialize session
    from repro.core.sysinfo import chip_profile_from_host, detect_host
    host = detect_host()
    campaign = carina.Campaign(
        carina.TrainingCampaign(f"train-{cfg.name}", cfg.name,
                                total_steps=args.steps, steps_per_unit=10),
        POLICIES[args.policy],
        name=f"train-{cfg.name}", out_dir="experiments/train_run")
    controller = campaign.controller(
        max_replicas=n_dev,
        clock=SimClock(start_hour=9.0, speedup=600.0),
        chip=chip_profile_from_host(host))
    campaign.tracker.meta["host"] = host
    res = run_training(model, opt, data,
                       LoopConfig(total_steps=args.steps, steps_per_unit=10,
                                  ckpt_dir=args.ckpt_dir, log_every=10),
                       controller=controller, supervisor=Supervisor(),
                       mesh_fn=mesh_fn if n_dev > 1 else None,
                       initial_replicas=n_dev)
    print(f"done at step {res.final_step}; restarts={res.restarts}")
    summary = campaign.finish(render=False)
    print(carina.render_run_dashboard(summary, "experiments/train_run"))


if __name__ == "__main__":
    main()
