"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required so smoke tests see 1 CPU device while the dry-run
process sees 512 host devices via XLA_FLAGS set before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod: 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1-axis 'data' mesh (CPU tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_mesh_for(devices: int, model_parallel: int = 1, pods: int = 1):
    """Elastic re-meshing helper: arrange `devices` into (pod, data, model)."""
    assert devices % (model_parallel * pods) == 0
    data = devices // (model_parallel * pods)
    if pods > 1:
        return jax.make_mesh((pods, data, model_parallel), ("pod", "data", "model"))
    if model_parallel > 1:
        return jax.make_mesh((data, model_parallel), ("data", "model"))
    return jax.make_mesh((data,), ("data",))
