"""Chunked diagonal linear-recurrence kernel: h_t = a_t * h_{t-1} + b_t.

Serves both Mamba-1 selective scans (C = d_inner * d_state, flattened) and
Griffin RG-LRU (C = lru_width).

TPU-native design: grid = (B, C/bc, T/chunk).  The time axis is the minor
(sequential) grid dim; the carried state h (bc,) lives in VMEM scratch and
persists across time-chunk iterations.  Channels are "parallel" — each
channel block scans its own recurrence, so the kernel parallelizes over
B x C/bc cells while time advances sequentially within each — the same
tiling as models/ssm.py's chunked_diag_scan, but with the chunk loop in
VMEM instead of XLA scan-carried HBM round-trips.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

F32 = jnp.float32


def _scan_kernel(a_ref, b_ref, hs_ref, hf_ref, h_ref, *, chunk):
    it = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(F32)                 # (chunk, bc)
    b = b_ref[0].astype(F32)

    def step(t, h):
        h = a[t] * h + b[t]
        hs_ref[0, t] = h.astype(hs_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[0])
    h_ref[0] = h

    @pl.when(it == nt - 1)
    def _emit():
        hf_ref[0] = h.astype(hf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_c", "interpret"))
def ssm_scan(a, b, *, chunk: int = 128, block_c: int = 512, interpret: bool = False):
    """a, b: (B, T, C). Returns (hs (B,T,C) fp32, h_final (B,C) fp32)."""
    B, T, C = a.shape
    bc = min(block_c, C)
    nc = -(-C // bc)
    ch = min(chunk, T)
    nt = -(-T // ch)
    c_p, t_p = nc * bc, nt * ch
    if c_p != C or t_p != T:
        a = jnp.pad(a, ((0, 0), (0, t_p - T), (0, c_p - C)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, t_p - T), (0, c_p - C)))

    grid = (B, nc, nt)
    hs, hf = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=ch),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ch, bc), lambda bi, ci, ti: (bi, ti, ci)),
            pl.BlockSpec((1, ch, bc), lambda bi, ci, ti: (bi, ti, ci)),
        ],
        out_specs=[
            pl.BlockSpec((1, ch, bc), lambda bi, ci, ti: (bi, ti, ci)),
            pl.BlockSpec((1, bc), lambda bi, ci, ti: (bi, ci)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, t_p, c_p), F32),
            jax.ShapeDtypeStruct((B, c_p), F32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bc), F32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return hs[:, :T, :C], hf[:, :C]
