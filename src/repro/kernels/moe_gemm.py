"""Grouped (per-expert) GEMM kernel — MegaBlocks-style block-diagonal matmul.

Contract: tokens are pre-sorted by expert and padded so every bm-row block
belongs to exactly one expert; `block_ids` (n_row_blocks,) gives that
expert.  block_ids is a scalar-prefetch operand (pltpu.PrefetchScalarGridSpec)
so the expert-weight BlockSpec index_map can select w[block_ids[im]] while
the block is being DMA'd — data-dependent weight streaming with no gather
materialization of (T, d, f).

Grid: (nm, nn, nkd); the d (contraction) axis is the sequential minor dim,
accumulating into the output tile (revisited across kd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

F32 = jnp.float32


def _gg_kernel(ids_ref, x_ref, w_ref, o_ref, acc_ref):
    kd = pl.program_id(2)
    nkd = pl.num_programs(2)

    @pl.when(kd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(F32), w_ref[0].astype(F32),
        (((1,), (0,)), ((), ())), preferred_element_type=F32)

    @pl.when(kd == nkd - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def grouped_gemm(x, w, block_ids, *, block_m: int = 128, block_n: int = 128,
                 block_k: int = 512, interpret: bool = False):
    """x: (T, d) block-sorted rows; w: (E, d, f); block_ids: (T//block_m,) int32.
    Returns (T, f)."""
    t, d = x.shape
    e, _, f = w.shape
    assert t % block_m == 0, (t, block_m)
    bn = min(block_n, f)
    bk = min(block_k, d)
    nm = t // block_m
    nn = -(-f // bn)
    nkd = -(-d // bk)
    f_p, d_p = nn * bn, nkd * bk
    if f_p != f or d_p != d:
        w = jnp.pad(w, ((0, 0), (0, d_p - d), (0, f_p - f)))
        x = jnp.pad(x, ((0, 0), (0, d_p - d)))

    grid = (nm, nn, nkd)
    o = pl.pallas_call(
        _gg_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, bk), lambda im, jn, kd, ids: (im, kd)),
                pl.BlockSpec((1, bk, bn), lambda im, jn, kd, ids: (ids[im], kd, jn)),
            ],
            out_specs=pl.BlockSpec((block_m, bn), lambda im, jn, kd, ids: (im, jn)),
            scratch_shapes=[pltpu.VMEM((block_m, bn), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((t, f_p), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_ids, x, w)
    return o[:, :f]
