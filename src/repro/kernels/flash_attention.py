"""Fused blockwise-softmax (flash) attention forward kernel for TPU.

TPU-native design (DESIGN.md §6):
  * grid = (B, H, Sq/bq, Sk/bk); the K axis is the minor (sequential) grid
    dim — online-softmax statistics (m, l) and the output accumulator live
    in VMEM scratch and carry across K iterations ("arbitrary" semantics).
  * q/k/v tiles are MXU-aligned (block sizes multiples of 128 where the
    shape allows); softmax statistics are stored (bq, 128) lane-replicated
    (Mosaic-friendly 2D layout).
  * GQA is handled in the K/V index_map (kv head = q head // group) — no
    jnp.repeat materialization.
  * causal masking skips fully-masked K blocks via pl.when.

Forward-only kernel + residuals (o, lse); the backward pass is a chunked
pure-XLA implementation wired through jax.custom_vjp in ops.py (recompute
per K block, flash-style memory).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

F32 = jnp.float32
NEG_INF = -1e30
LANES = 128


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                acc_ref, m_ref, l_ref, *, scale, causal, bq, bk, sk):
    ik = pl.program_id(3)
    iq = pl.program_id(2)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq
    k_start = ik * bk
    # causal: skip blocks entirely above the diagonal
    run = True
    if causal:
        run = k_start <= q_start + bq - 1

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0, 0].astype(F32)                       # (bq, d)
        k = k_ref[0, 0].astype(F32)                       # (bk, d)
        v = v_ref[0, 0].astype(F32)                       # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32) * scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < sk
        if causal:
            mask &= kpos <= qpos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                             # (bq, 1)
        l_prev = l_ref[:, :1]
        m_blk = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new)                            # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)
        lse = m_ref[:, :1] + jnp.log(l_safe)
        lse_ref[0, 0] = jnp.broadcast_to(lse, lse_ref.shape[2:]).astype(F32)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q", "block_k",
                                             "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        scale: Optional[float] = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D) -> (o (B,H,Sq,D), lse (B,H,Sq,LANES))."""
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    nq = -(-sq // bq)
    nk = -(-sk // bk)
    # pad sequence dims to block multiples
    sq_p, sk_p = nq * bq, nk * bk
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))

    grid = (b, h, nq, nk)
    kernel = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, sk=sk)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki, _g=g: (bi, hi // _g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki, _g=g: (bi, hi // _g, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bq, LANES), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sq_p, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, sq_p, LANES), F32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, d), F32),
            pltpu.VMEM((bq, LANES), F32),
            pltpu.VMEM((bq, LANES), F32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return o[:, :, :sq], lse[:, :, :sq, 0]
