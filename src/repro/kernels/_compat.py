"""Pallas API compatibility across jax versions.

jax renamed the TPU compiler-params dataclass (`TPUCompilerParams` ->
`CompilerParams`); resolve whichever this jax ships so the kernels run on
both sides of the rename.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
