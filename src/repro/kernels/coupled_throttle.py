"""Pallas kernel for the site-coupled chunk step of the trace engine.

The coupled chunk is the one hot path the generic `jax.lax.scan`
lowering handles worst: every slot does a per-group segment-sum of the
active lanes' draw plus `model.SITE_THROTTLE_ITERS` damped fixed-point
steps of `model.site_throttle`, and the scatter-add (`.at[gid].add`)
keeps round-tripping lane state through HBM between slots.

This kernel restages the problem in a dense ``(group, lane)`` layout:
grid = (G,), one program per group ("parallel" — groups never
interact), with the whole slot loop running inside the kernel as a
`jax.lax.fori_loop` whose carry is the scan state.  The segment-sum
collapses to a plain `jnp.sum` over the program's own lane block, and
the per-lane decision-row gather is hoisted *outside* the kernel by the
caller (rows are pre-gathered to ``(G, Lp, C, B)``), so the kernel body
is pure dense arithmetic.

Progress-bucket interpolation is expressed as a hat-function weighted
sum over bucket centers — mathematically identical to the engine's
`_bucket_lookup` two-point interpolation (the hat weights are zero
except at the same two buckets, and adding exact fp zeros is exact) —
because a dynamic per-lane gather of ``b0`` would defeat the dense
layout.  Numerical parity with the jnp coupled kernel is pinned to
<1e-9 by tests/test_scaleout.py and the fleet oracle tests.

The engine treats this module as optional: import failures or
non-TPU backends without ``interpret=True`` fall back to the jnp
kernel (see `_resolve_pallas` in core/engine_jax.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import model
from repro.kernels._compat import CompilerParams


def _kernel(u_ref, b_ref, bg_ref, cf_ref, pr_ref, lens_ref, cap_ref,
            off_ref, rem_ref, rt_ref, kwh_ref, co2_ref, cost_ref,
            speak_ref, nsc_ref, rate_ref, oh_ref, idle_ref, dyn_ref,
            alpha_ref, gamma_ref, ohf_ref,
            rem_o, rt_o, kwh_o, co2_o, cost_o, speak_o,
            *, C, B, iters, finish_frac):
    u_tab = u_ref[0]                    # (Lp, C, B)
    b_tab = b_ref[0]
    bg = bg_ref[0]                      # (Lp, C)
    cf = cf_ref[0]                      # (Lp, E, C)
    pr = pr_ref[0]
    lens = lens_ref[0]
    cap = cap_ref[0]                    # scalar: this group's site cap
    off = off_ref[0]                    # (C,) office draw over the chunk
    nsc = nsc_ref[0]
    rate = rate_ref[0]
    oh = oh_ref[0]
    idle = idle_ref[0]
    dyn = dyn_ref[0]
    alpha = alpha_ref[0]
    gamma = gamma_ref[0]
    ohf = ohf_ref[0]
    Lp = u_tab.shape[0]
    centers = jax.lax.broadcasted_iota(u_tab.dtype, (Lp, B), 1)

    def step(t, carry):
        rem, rt, kwh, co2, cost, speak = carry
        # mixed precision: carried state is fp64, physics runs at the
        # tables' dtype (no-op cast on fp64 plans)
        prog = (1.0 - rem / nsc).astype(u_tab.dtype)
        if B == 1:
            u = u_tab[:, t, 0]
            bt = b_tab[:, t, 0]
        else:
            x = jnp.clip(prog * B - 0.5, 0.0, B - 1.0)
            w = jnp.maximum(1.0 - jnp.abs(x[:, None] - centers), 0.0)
            u = jnp.sum(u_tab[:, t, :] * w, axis=-1)
            bt = jnp.sum(b_tab[:, t, :] * w, axis=-1)
        bg_t = bg[:, t]
        r = model.rates(u, bt, bg_t, rate_at_full=rate,
                        batch_overhead_s=oh, idle_w=idle, dyn_w=dyn,
                        alpha=alpha, gamma=gamma, overhead_w_frac=ohf,
                        xp=jnp)
        active = rem > finish_frac * nsc
        base = jnp.sum(jnp.where(
            active, model.power_w(bg_t, idle, dyn, alpha, xp=jnp),
            0.0) / 1000.0)
        head = cap - off[t]
        f = jnp.asarray(1.0, u.dtype)
        r2 = r
        for _ in range(iters):
            draw = jnp.sum(jnp.where(active, r2.p_avg_w, 0.0) / 1000.0)
            f = model.site_throttle(draw, base, head, f, xp=jnp)
            r2 = model.rates(u * f, bt, bg_t, rate_at_full=rate,
                             batch_overhead_s=oh, idle_w=idle, dyn_w=dyn,
                             alpha=alpha, gamma=gamma,
                             overhead_w_frac=ohf, xp=jnp)
        dt = jnp.where(
            rem > 0.0,
            jnp.minimum(lens[:, t],
                        rem / jnp.maximum(r2.scen_per_s, 1e-30)),
            0.0)
        e = r2.kwh_per_s * dt
        site_kw = jnp.sum(jnp.where(active, r2.p_avg_w, 0.0)
                          / 1000.0) + off[t]
        speak = jnp.where(active, jnp.maximum(speak, site_kw), speak)
        return (rem - r2.scen_per_s * dt, rt + dt, kwh + e,
                co2 + e[:, None] * cf[:, :, t], cost + e * pr[:, t],
                speak)

    init = (rem_ref[0], rt_ref[0], kwh_ref[0], co2_ref[0], cost_ref[0],
            speak_ref[0])
    rem, rt, kwh, co2, cost, speak = jax.lax.fori_loop(0, C, step, init)
    rem_o[0] = rem
    rt_o[0] = rt
    kwh_o[0] = kwh
    co2_o[0] = co2
    cost_o[0] = cost
    speak_o[0] = speak


@functools.partial(jax.jit,
                   static_argnames=("iters", "finish_frac", "interpret"))
def coupled_chunk(u_rows, b_rows, bg, cf, pr, lens, cap_g, office,
                  remaining, rt, kwh, co2, cost, speak,
                  n_scen, rate, oh, idle, dyn, alpha, gamma, ohfrac,
                  *, iters: int, finish_frac: float,
                  interpret: bool = False):
    """One coupled chunk over a dense ``(G, Lp, ...)`` group layout.

    `u_rows`/`b_rows` are the *pre-gathered* decision rows
    ``tab[lane, rowidx[lane, t], :]`` with shape ``(G, Lp, C, B)``;
    `bg`/`pr`/`lens` are ``(G, Lp, C)``, `cf` is ``(G, Lp, E, C)``,
    `cap_g` is ``(G,)``, `office` is ``(G, C)``, and state/scalars are
    ``(G, Lp)`` (co2 ``(G, Lp, E)``).  Padded lanes must carry the
    engine's standard safe fills (remaining 0 → inactive, n_scen 1,
    alpha 1) and padded groups an infinite cap.  Returns the six state
    arrays after C slots.
    """
    G, Lp, C, B = u_rows.shape
    E = cf.shape[2]

    def lane2(g):
        return (g, 0)

    def lane3(g):
        return (g, 0, 0)

    def lane4(g):
        return (g, 0, 0, 0)

    def group1(g):
        return (g,)

    in_specs = [
        pl.BlockSpec((1, Lp, C, B), lane4),          # u_rows
        pl.BlockSpec((1, Lp, C, B), lane4),          # b_rows
        pl.BlockSpec((1, Lp, C), lane3),             # bg
        pl.BlockSpec((1, Lp, E, C), lane4),          # cf
        pl.BlockSpec((1, Lp, C), lane3),             # pr
        pl.BlockSpec((1, Lp, C), lane3),             # lens
        pl.BlockSpec((1,), group1),                  # cap_g
        pl.BlockSpec((1, C), lane2),                 # office
        pl.BlockSpec((1, Lp), lane2),                # remaining
        pl.BlockSpec((1, Lp), lane2),                # rt
        pl.BlockSpec((1, Lp), lane2),                # kwh
        pl.BlockSpec((1, Lp, E), lane3),             # co2
        pl.BlockSpec((1, Lp), lane2),                # cost
        pl.BlockSpec((1, Lp), lane2),                # speak
    ] + [pl.BlockSpec((1, Lp), lane2)] * 8           # physics scalars
    out_specs = [
        pl.BlockSpec((1, Lp), lane2),
        pl.BlockSpec((1, Lp), lane2),
        pl.BlockSpec((1, Lp), lane2),
        pl.BlockSpec((1, Lp, E), lane3),
        pl.BlockSpec((1, Lp), lane2),
        pl.BlockSpec((1, Lp), lane2),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((G, Lp), remaining.dtype),
        jax.ShapeDtypeStruct((G, Lp), rt.dtype),
        jax.ShapeDtypeStruct((G, Lp), kwh.dtype),
        jax.ShapeDtypeStruct((G, Lp, E), co2.dtype),
        jax.ShapeDtypeStruct((G, Lp), cost.dtype),
        jax.ShapeDtypeStruct((G, Lp), speak.dtype),
    ]
    return pl.pallas_call(
        functools.partial(_kernel, C=C, B=B, iters=iters,
                          finish_frac=finish_frac),
        grid=(G,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(u_rows, b_rows, bg, cf, pr, lens, cap_g, office,
      remaining, rt, kwh, co2, cost, speak,
      n_scen, rate, oh, idle, dyn, alpha, gamma, ohfrac)
