"""Flash-decoding attention kernel for serve_step (q_len = 1).

Design: the 32k-long KV cache is the bandwidth-bound operand; we split it
into `nsplit` slices processed by parallel grid cells.  Each cell streams
its slice through VMEM in bk-sized blocks (sequential minor grid dim),
maintaining online-softmax partials in VMEM scratch, and emits
(o_partial * l, m, l) per split.  The final rescale-combine over splits is
O(nsplit*d) and runs as a tiny XLA epilogue in the wrapper.

Grid: (B, Hkv, nsplit, nk_per_split).  All q heads of one KV head (the GQA
group, rows of q) are processed together: q tile is (g, d) so the score
matmul (g, d) x (d, bk) feeds the MXU with the group as the M dim.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

F32 = jnp.float32
NEG_INF = -1e30
LANES = 128


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, ms_ref, ls_ref, *, scale, bk, per_split):
    isplit = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    length = len_ref[0]
    k_start = isplit * per_split + ik * bk

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(F32)                      # (g, d)
        k = k_ref[0, 0].astype(F32)                      # (bk, d)
        v = v_ref[0, 0].astype(F32)                      # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32) * scale  # (g, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = ms_ref[:, :1]
        l_prev = ls_ref[:, :1]
        m_blk = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        ls_ref[...] = jnp.broadcast_to(l_prev * alpha + jnp.sum(p, axis=1, keepdims=True),
                                       ls_ref.shape)
        ms_ref[...] = jnp.broadcast_to(m_new, ms_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)

    @pl.when(ik == nk - 1)
    def _emit():
        o_ref[0, 0, 0] = (acc_ref[...]).astype(o_ref.dtype)  # un-normalized (o*l)
        m_ref[0, 0, 0] = ms_ref[...].astype(F32)
        l_ref[0, 0, 0] = ls_ref[...].astype(F32)


@functools.partial(jax.jit, static_argnames=("nsplit", "block_k", "interpret", "scale"))
def decode_attention(q, k, v, length, *, nsplit: int = 8, block_k: int = 256,
                     scale: Optional[float] = None, interpret: bool = False):
    """q: (B, H, D); k, v: (B, Sk, Hkv, D); length: scalar int32 valid prefix.
    Returns (B, H, D)."""
    b, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    # layout: (B, Hkv, Sk, D) for contiguous streaming
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    qg = q.reshape(b, hkv, g, d)

    nsplit = max(1, min(nsplit, sk // block_k or 1))
    per_split = -(-sk // nsplit)
    bk = min(block_k, per_split)
    nk = -(-per_split // bk)
    per_split = nk * bk
    sk_p = per_split * nsplit
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))

    length = jnp.asarray(length, jnp.int32).reshape(1)
    grid = (b, hkv, nsplit, nk)
    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk,
                               per_split=per_split)
    o_p, m_p, l_p = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, si, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, si, ki, _nk=nk: (bi, hi, si * _nk + ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, si, ki, _nk=nk: (bi, hi, si * _nk + ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, g, d), lambda bi, hi, si, ki: (bi, hi, si, 0, 0)),
            pl.BlockSpec((1, 1, 1, g, LANES), lambda bi, hi, si, ki: (bi, hi, si, 0, 0)),
            pl.BlockSpec((1, 1, 1, g, LANES), lambda bi, hi, si, ki: (bi, hi, si, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, nsplit, g, d), F32),
            jax.ShapeDtypeStruct((b, hkv, nsplit, g, LANES), F32),
            jax.ShapeDtypeStruct((b, hkv, nsplit, g, LANES), F32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, d), F32),
            pltpu.VMEM((g, LANES), F32),
            pltpu.VMEM((g, LANES), F32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(length, qg, k, v)

    # combine splits (tiny XLA epilogue)
    m = m_p[..., 0]                                         # (B,Hkv,ns,g)
    l = l_p[..., 0]
    m_max = jnp.max(m, axis=2, keepdims=True)
    w = jnp.exp(m - m_max) * jnp.where(l > 0, 1.0, 0.0)
    l_tot = jnp.sum(l * jnp.exp(m - m_max), axis=2)         # (B,Hkv,g)
    o = jnp.sum(o_p * (jnp.exp(m - m_max) )[..., None], axis=2)
    o = o / jnp.maximum(l_tot, 1e-30)[..., None]
    del w
    return o.reshape(b, h, d).astype(q.dtype)
