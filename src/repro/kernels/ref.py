"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are deliberately naive/obvious implementations — O(S^2) attention with
materialized scores, step-by-step scans — used by tests/test_kernels.py to
validate the kernels in interpret mode across shape/dtype sweeps.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


# ---------------------------------------------------------------------------
def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: Optional[float] = None,
                        window: int = 0) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D). Returns (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(F32), k.astype(F32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    ok = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        ok &= kpos <= qpos + (k.shape[2] - sq)   # offset when Sk > Sq
    if window > 0:
        ok &= (qpos + (k.shape[2] - sq)) - kpos < window
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(F32)).astype(q.dtype)


def flash_attention_lse_ref(q, k, v, *, causal: bool = True,
                            scale: Optional[float] = None):
    """Also return logsumexp (B, H, Sq) fp32 — for the bwd pass contract."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(F32), kk.astype(F32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(kk.shape[2])[None, :]
    if causal:
        ok = kpos <= qpos + (kk.shape[2] - sq)
        s = jnp.where(ok[None, None], s, NEG_INF)
    lse = jax.nn.logsumexp(s, axis=-1)
    p = jnp.exp(s - lse[..., None])
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(F32))
    return o.astype(q.dtype), lse


def decode_attention_ref(q, k, v, length) -> jax.Array:
    """q: (B, H, D); k, v: (B, Sk, Hkv, D); length: valid prefix len (scalar).
    Returns (B, H, D)."""
    b, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    kk = jnp.repeat(k, g, axis=2)                      # (B, Sk, H, D)
    vv = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(F32), kk.astype(F32)) * scale
    valid = jnp.arange(k.shape[1]) < length
    s = jnp.where(valid[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, vv.astype(F32)).astype(q.dtype)


def ssm_scan_ref(a, b, h0=None) -> Tuple[jax.Array, jax.Array]:
    """Diagonal linear recurrence h_t = a_t*h_{t-1} + b_t.
    a, b: (B, T, C). Returns (hs (B,T,C) fp32, h_final (B,C) fp32)."""
    B, T, C = a.shape
    h = jnp.zeros((B, C), F32) if h0 is None else h0.astype(F32)

    def step(h, xs):
        at, bt = xs
        h = at.astype(F32) * h + bt.astype(F32)
        return h, h

    h_final, hs = jax.lax.scan(step, h, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), h_final


def rmsnorm_ref(x, scale, eps: float = 1e-6) -> jax.Array:
    """x: (T, d); scale: (d,)."""
    xf = x.astype(F32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(F32))).astype(x.dtype)


def grouped_gemm_ref(x, w, group_sizes) -> jax.Array:
    """x: (T, d) rows grouped by expert (sizes sum to T); w: (E, d, f).
    Row i belongs to expert e where cumsum(group_sizes) gives boundaries.
    Returns (T, f)."""
    t, d = x.shape
    e = w.shape[0]
    bounds = jnp.cumsum(group_sizes)
    rows = jnp.arange(t)
    gid = jnp.sum(rows[:, None] >= bounds[None, :], axis=1)  # (T,)
    wg = w[gid]                                              # (T, d, f) gather
    return jnp.einsum("td,tdf->tf", x.astype(F32), wg.astype(F32)).astype(x.dtype)


def blocked_xent_ref(x, emb, labels) -> jax.Array:
    """Full-logits CE oracle. x: (T,d), emb: (V,d), labels: (T,). fp32 nll (T,)."""
    logits = jnp.einsum("td,vd->tv", x.astype(F32), emb.astype(F32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - ll
