"""Vocab-blocked fused softmax cross-entropy kernel.

The (T, V) logits matrix never exists: grid = (T/bt, V/bv) with the vocab
axis sequential; each cell computes a (bt, bv) logits tile on the MXU from
the resident (bt, d) hidden tile and the streamed (bv, d) embedding tile,
updating running (max, sumexp, label-logit) statistics in VMEM scratch.
Final NLL is emitted on the last vocab block.

This is the kernel twin of models/loss.py:blocked_cross_entropy (the
XLA-scan formulation used off-TPU); both are validated against
kernels/ref.py:blocked_xent_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

F32 = jnp.float32
NEG_INF = -1e30
LANES = 128


def _xent_kernel(x_ref, e_ref, lab_ref, nll_ref, m_ref, s_ref, ll_ref,
                 *, bv, v, bt):
    jv = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(jv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        ll_ref[...] = jnp.full_like(ll_ref, NEG_INF)

    x = x_ref[...].astype(F32)                              # (bt, d)
    e = e_ref[...].astype(F32)                              # (bv, d)
    logits = jax.lax.dot_general(x, e, (((1,), (1,)), ((), ())),
                                 preferred_element_type=F32)  # (bt, bv)
    base = jv * bv
    col = base + jax.lax.broadcasted_iota(jnp.int32, (bt, bv), 1)
    logits = jnp.where(col < v, logits, NEG_INF)

    m_prev = m_ref[:, :1]
    blk_max = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, blk_max)
    s_ref[...] = jnp.broadcast_to(
        s_ref[:, :1] * jnp.exp(m_prev - m_new)
        + jnp.sum(jnp.exp(logits - m_new), axis=1, keepdims=True), s_ref.shape)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    labels = lab_ref[:, :1]                                 # (bt, 1) int32
    in_blk = (labels >= base) & (labels < base + bv)
    hit = (col == labels)                                   # (bt, bv)
    cand = jnp.max(jnp.where(hit, logits, NEG_INF), axis=1, keepdims=True)
    ll_ref[...] = jnp.where(jnp.broadcast_to(in_blk, ll_ref.shape),
                            jnp.broadcast_to(cand, ll_ref.shape), ll_ref[...])

    @pl.when(jv == nv - 1)
    def _emit():
        nll = m_ref[:, :1] + jnp.log(s_ref[:, :1]) - ll_ref[:, :1]
        nll_ref[...] = jnp.broadcast_to(nll, nll_ref.shape).astype(F32)


@functools.partial(jax.jit, static_argnames=("block_t", "block_v", "interpret"))
def blocked_xent(x, emb, labels, *, block_t: int = 256, block_v: int = 2048,
                 interpret: bool = False):
    """x: (T, d); emb: (V, d); labels: (T,) int32. Returns nll (T,) fp32."""
    t, d = x.shape
    v = emb.shape[0]
    bt = min(block_t, t)
    bv = min(block_v, v)
    nt, nv = -(-t // bt), -(-v // bv)
    t_p, v_p = nt * bt, nv * bv
    if t_p != t:
        x = jnp.pad(x, ((0, t_p - t), (0, 0)))
        labels = jnp.pad(labels, (0, t_p - t))
    if v_p != v:
        emb = jnp.pad(emb, ((0, v_p - v), (0, 0)))
    labels2 = jnp.broadcast_to(labels[:, None], (t_p, LANES)).astype(jnp.int32)

    nll = pl.pallas_call(
        functools.partial(_xent_kernel, bv=bv, v=v, bt=bt),
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((bt, d), lambda it, jv: (it, 0)),
            pl.BlockSpec((bv, d), lambda it, jv: (jv, 0)),
            pl.BlockSpec((bt, LANES), lambda it, jv: (it, 0)),
        ],
        out_specs=pl.BlockSpec((bt, LANES), lambda it, jv: (it, 0)),
        out_shape=jax.ShapeDtypeStruct((t_p, LANES), F32),
        scratch_shapes=[
            pltpu.VMEM((bt, LANES), F32),
            pltpu.VMEM((bt, LANES), F32),
            pltpu.VMEM((bt, LANES), F32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, emb, labels2)
    return nll[:t, 0]
