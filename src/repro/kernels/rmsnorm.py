"""Fused RMSNorm kernel: one HBM pass (read x, write y) instead of XLA's
separate square/mean/rsqrt/mul chain.  Row-tiled: grid = (T/bt); each cell
loads a (bt, d) tile into VMEM, reduces, scales, writes back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

F32 = jnp.float32


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps, d):
    x = x_ref[...].astype(F32)                          # (bt, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(F32))[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_t", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_t: int = 256,
            interpret: bool = False):
    """x: (T, d); scale: (d,). Returns (T, d) in x.dtype."""
    t, d = x.shape
    bt = min(block_t, t)
    nt = -(-t // bt)
    t_p = nt * bt
    if t_p != t:
        x = jnp.pad(x, ((0, t_p - t), (0, 0)))
    o = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, d=d),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t_p, d), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, scale)
    return o[:t]
