"""Public jit'd kernel API with backend dispatch.

Modes (ModelConfig.kernels):
  "auto"   -> Pallas kernels on TPU, pure-XLA paths elsewhere (CPU dev
              container, dry-run AOT compiles on host devices)
  "xla"    -> always pure-XLA
  "pallas" -> always Pallas (tests pass interpret=True on CPU)

`flash_attention` is differentiable: Pallas forward (o, lse) + a chunked
pure-XLA backward (recompute-per-KV-block, flash-style memory) wired via
jax.custom_vjp.  Layout: (B, S, H, D) to match the model stack.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as FA
from repro.kernels import decode_attention as DA
from repro.kernels import ssm_scan as SS
from repro.kernels import rmsnorm as RN
from repro.kernels import moe_gemm as GG
from repro.kernels import xent as XE

F32 = jnp.float32


def use_pallas(mode: str = "auto") -> bool:
    if mode == "pallas":
        return True
    if mode == "xla":
        return False
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# flash attention, differentiable, (B, S, H, D) layout
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, scale: Optional[float] = None,
                    interpret: bool = False):
    """q: (B,Sq,H,D); k,v: (B,Sk,Hkv,D) -> (B,Sq,H,D)."""
    o, _ = _fa_fwd_impl(q, k, v, causal, scale, interpret)
    return o


def _fa_fwd_impl(q, k, v, causal, scale, interpret):
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o, lse = FA.flash_attention_fwd(qt, kt, vt, causal=causal, scale=scale,
                                    interpret=interpret)
    return o.transpose(0, 2, 1, 3), lse


def _fa_fwd(q, k, v, causal, scale, interpret):
    o, lse = _fa_fwd_impl(q, k, v, causal, scale, interpret)
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, scale, interpret, res, do):
    q, k, v, o, lse = res
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(d)

    # (B, Hkv, G, S, D) views, fp32 math
    qf = q.astype(F32).reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)
    kf = k.astype(F32).transpose(0, 2, 1, 3)                    # (B,Hkv,Sk,D)
    vf = v.astype(F32).transpose(0, 2, 1, 3)
    dof = do.astype(F32).reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)
    of = o.astype(F32).reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)
    lsef = lse.reshape(b, hkv, g, sq)
    dsum = jnp.sum(dof * of, axis=-1)                           # (B,Hkv,G,Sq)

    chunk = 1024
    nq = -(-sq // chunk)
    pad = nq * chunk - sq
    if pad:
        def padq(t):
            return jnp.pad(t, ((0, 0), (0, 0), (0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 4))
        qf, dof, lsef, dsum = padq(qf), padq(dof), padq(lsef), padq(dsum)
    qc = jnp.moveaxis(qf.reshape(b, hkv, g, nq, chunk, d), 3, 0)
    doc = jnp.moveaxis(dof.reshape(b, hkv, g, nq, chunk, d), 3, 0)
    lsec = jnp.moveaxis(lsef.reshape(b, hkv, g, nq, chunk), 3, 0)
    dsc = jnp.moveaxis(dsum.reshape(b, hkv, g, nq, chunk), 3, 0)
    kpos = jnp.arange(sk, dtype=jnp.int32)

    def body(carry, inp):
        dk_acc, dv_acc = carry
        ci, q_c, do_c, lse_c, ds_c = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", q_c, kf) * sc
        if causal:
            qpos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
            mask = kpos[None, :] <= qpos[:, None]
            s = jnp.where(mask[None, None, None], s, -1e30)
        p = jnp.exp(s - lse_c[..., None])                       # (B,Hkv,G,cq,Sk)
        dv_acc = dv_acc + jnp.einsum("bhgqk,bhgqd->bhkd", p, do_c)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", do_c, vf)
        ds = p * (dp - ds_c[..., None]) * sc
        dq_c = jnp.einsum("bhgqk,bhkd->bhgqd", ds, kf)
        dk_acc = dk_acc + jnp.einsum("bhgqk,bhgqd->bhkd", ds, q_c)
        return (dk_acc, dv_acc), dq_c

    init = (jnp.zeros((b, hkv, sk, d), F32), jnp.zeros((b, hkv, sk, d), F32))
    (dk, dv), dqs = jax.lax.scan(
        body, init, (jnp.arange(nq), qc, doc, lsec, dsc))
    dq = jnp.moveaxis(dqs, 0, 3).reshape(b, hkv, g, nq * chunk, d)[:, :, :, :sq]
    dq = dq.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)
    dk = dk.transpose(0, 2, 1, 3).astype(k.dtype)
    dv = dv.transpose(0, 2, 1, 3).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# thin dispatch wrappers
# ---------------------------------------------------------------------------
def decode_attention(q, k, v, length, *, mode: str = "auto", interpret: bool = False):
    """q: (B,H,D); k,v: (B,Sk,Hkv,D); length scalar."""
    if use_pallas(mode) or interpret:
        return DA.decode_attention(q, k, v, length, interpret=interpret)
    from repro.kernels import ref
    return ref.decode_attention_ref(q, k, v, length)


def ssm_scan(a, b, *, mode: str = "auto", interpret: bool = False):
    if use_pallas(mode) or interpret:
        return SS.ssm_scan(a, b, interpret=interpret)
    from repro.kernels import ref
    return ref.ssm_scan_ref(a, b)


def rmsnorm(x, scale, *, eps: float = 1e-6, mode: str = "auto",
            interpret: bool = False):
    if use_pallas(mode) or interpret:
        return RN.rmsnorm(x, scale, eps=eps, interpret=interpret)
    from repro.kernels import ref
    return ref.rmsnorm_ref(x, scale, eps)


def grouped_gemm(x, w, block_ids, *, block_m: int = 128, mode: str = "auto",
                 interpret: bool = False):
    return GG.grouped_gemm(x, w, block_ids, block_m=block_m, interpret=interpret)


def blocked_xent(x, emb, labels, *, mode: str = "auto", interpret: bool = False):
    if use_pallas(mode) or interpret:
        return XE.blocked_xent(x, emb, labels, interpret=interpret)
    from repro.kernels import ref
    return ref.blocked_xent_ref(x, emb, labels)
