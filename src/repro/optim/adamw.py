"""Sharded AdamW with dtype-configurable moments and global-norm clipping.

Moments inherit each parameter's sharding (the tree is mapped leaf-wise, so
under pjit the optimizer state is ZeRO-sharded exactly like the params).
`state_dtype="bfloat16"` halves optimizer HBM for the largest models
(llama3-405b train fits 256 v5e chips only with bf16 moments — see
EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(F32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(params_abstract, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {
        "m": jax.tree.map(z, params_abstract),
        "v": jax.tree.map(z, params_abstract),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.ones(())
    dt = jnp.dtype(cfg.state_dtype)
    bc1 = 1 - cfg.b1 ** step.astype(F32)
    bc2 = 1 - cfg.b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m_new = cfg.b1 * m.astype(F32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(F32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(F32)
        p_new = p.astype(F32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(dt), v_new.astype(dt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
