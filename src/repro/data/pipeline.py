"""Deterministic synthetic data pipeline.

Token streams are a pure function of (seed, step, position) via a splitmix-
style integer hash — no host RNG state, so any replica can regenerate any
shard (exactly what checkpoint-restart and elastic resizing need: after a
restore the pipeline resumes from the step counter alone).

A background-thread prefetcher overlaps host batch synthesis with device
compute (the CPU-workstation analogue of an input pipeline; on TPU the same
iterator feeds device_put with the dp-sharded layout).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig


def _hash64(x: np.ndarray) -> np.ndarray:
    x = (x ^ (x >> 30)) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> 27)) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> 31)


def synth_tokens(seed: int, step: int, batch: int, seq: int, vocab: int,
                 start_row: int = 0) -> np.ndarray:
    """(batch, seq) int32 tokens, deterministic in (seed, step, row, col)."""
    rows = (start_row + np.arange(batch, dtype=np.uint64))[:, None]
    cols = np.arange(seq, dtype=np.uint64)[None, :]
    base = (np.uint64(seed) << np.uint64(40)) ^ (np.uint64(step) << np.uint64(20))
    h = _hash64(base ^ (rows << np.uint64(32)) ^ cols)
    return (h % np.uint64(vocab)).astype(np.int32)


class SyntheticLM:
    """Batch source for one arch config."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        out: Dict[str, np.ndarray] = {
            "tokens": synth_tokens(self.seed, step, self.batch, self.seq,
                                   cfg.vocab_size)}
        if cfg.family == "vlm":
            h = synth_tokens(self.seed + 1, step, self.batch,
                             cfg.n_vision_tokens * cfg.d_model, 65536)
            out["vision_embeds"] = (
                (h.reshape(self.batch, cfg.n_vision_tokens, cfg.d_model)
                 .astype(np.float32) / 32768.0 - 1.0) * 0.02).astype(np.float32)
        if cfg.encdec:
            h = synth_tokens(self.seed + 2, step, self.batch,
                             self.seq * cfg.d_model, 65536)
            out = {
                "frames": ((h.reshape(self.batch, self.seq, cfg.d_model)
                            .astype(np.float32) / 32768.0 - 1.0) * 0.02
                           ).astype(np.float32),
                "tokens": synth_tokens(self.seed, step, self.batch,
                                       cfg.dec_train_len, cfg.vocab_size),
            }
        return out

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-bounded)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
