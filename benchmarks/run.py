"""Benchmark harness — one function per paper table/figure plus the
roofline/kernel benches.  Prints ``name,us_per_call,derived`` CSV rows.

  fig1_policy_frontier   Figure 1: runtime-penalty vs energy-savings frontier
  frontier_sweep         vectorized sweep engine vs sequential simulation
                         (120 schedules in one NumPy pass; core/engine.py)
  trace_sweep            trace-grid JAX scan vs sequential simulation on a
                         7-day carbon trace at S in {10, 120, 1000} cases
                         (core/engine_jax.py)
  ensemble_sweep         chunked resumable scan + carbon ensembles: S x E
                         scenarios/sec, chunked-vs-monolithic wasted-work
                         ratio on a mixed-finish S=1000 batch, jit-recompile
                         count across repeated sweeps
  optimize_sweep         schedule-optimizer objective throughput: one jitted
                         population step (256+ candidates/call) vs the NumPy
                         loop backend, plus end-to-end Campaign.optimize
                         (core/optimize.py)
  fleet_sweep            grouped-lane fleet engine under a site cap: M x S
                         scenarios/sec, grouped-lane vs python-loop-over-
                         campaigns speedup at M=8 S=500, oracle agreement,
                         jit-recompile count across varying fleet widths
                         (core/fleet.py + the coupled chunk kernels)
  scaleout_sweep         device fan-out + precision policy: scenarios/sec
                         vs virtual CPU device count at S in {1e3,1e4,1e5},
                         fp64 vs mixed, via per-cell subprocesses (XLA reads
                         the fan-out flag once at init); also writes
                         BENCH_scaleout.json for the CI artifact trail
  recurrence_sweep       recurrence as a cache hit: cold vs warm-process
                         compile+sweep end-to-end via the disk plan cache
                         (bar >=5x), delta_sweep slot-work ratio at S=1000
                         for K in {1,10,100} changed schedules; writes
                         BENCH_recurrence.json for the CI artifact trail
  calibration_sweep      measured-run calibration: fit wall-time and
                         recovered-parameter error at U in {1e3, 1e4}
                         synthetic logged units (jax Adam vs the numpy FD
                         fallback), multi-zone (S, zone) batched sweep vs a
                         per-zone python loop; writes BENCH_calibration.json
                         for the CI artifact trail (core/calibrate.py +
                         core/data.py)
  serving_sweep          request-level scheduler: batched window scheduling
                         + execution throughput at 20k requests across the
                         four load shapes, CO2 saved vs carbon-blind FIFO,
                         vectorized-FIFO vs per-request python loop speedup,
                         jit-shape count (core/serve.py)
  oem_case_studies       §3 case-study table (measured vs simulated vs paper)
  campaign_projection    CARINA applied to a TPU training campaign (dry-run
                         StepCost -> kWh/CO2e for a real recurring retrain)
  roofline_table         §Roofline terms per (arch x shape) from the dry-run
  kernel_micro           CPU micro-timings of the XLA twin paths
"""
from __future__ import annotations

import dataclasses as _dataclasses
import glob
import json
import os
import statistics
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def _t(fn, n=5, warmup=2):
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts) * 1e6


def emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------------
def fig1_policy_frontier():
    from repro.core import policy_frontier
    from repro.core.workload import OEM_CASE_1

    t0 = time.perf_counter()
    res = policy_frontier(OEM_CASE_1)
    us = (time.perf_counter() - t0) * 1e6
    for r in res:
        emit(f"fig1/{r.policy}", us / len(res),
             f"dT={r.runtime_delta_pct:+.2f}%_dE={r.energy_delta_pct:+.2f}%")
    boosted = next(r for r in res if "boosted" in r.policy)
    emit("fig1/paper_claim_boosted", 0.0,
         f"paper(-9%,+7%)_ours({boosted.energy_delta_pct:+.1f}%,"
         f"{boosted.runtime_delta_pct:+.1f}%)")


def frontier_sweep():
    """Vectorized sweep engine vs sequential simulate_campaign on a
    120-schedule candidate set (acceptance bar: >=10x on >=100 schedules)."""
    from repro.core import (MachineProfile, SweepCase, calibrate_workload,
                            constant_schedule, hourly_schedule,
                            simulate_campaign, sweep)
    from repro.core.workload import OEM_CASE_1

    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    scheds = ([constant_schedule(0.10 + 0.90 * i / 59) for i in range(60)]
              + [hourly_schedule(f"hourly_{i}",
                                 [0.2 + 0.8 * ((3 * i + h) % 24) / 23
                                  for h in range(24)]) for i in range(60)])
    cases = [SweepCase(s, wl, m) for s in scheds]
    sweep(cases[:2])                      # warm engine caches
    simulate_campaign(wl, scheds[0], m)

    t0 = time.perf_counter()
    seq = [simulate_campaign(wl, s, m) for s in scheds]
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    vec = sweep(cases)
    t_vec = time.perf_counter() - t0
    err = max(abs(a.energy_kwh / b.energy_kwh - 1) for a, b in zip(vec, seq))
    emit("sweep/sequential_120", t_seq * 1e6 / len(scheds),
         f"total_ms={t_seq * 1e3:.1f}")
    emit("sweep/vectorized_120", t_vec * 1e6 / len(scheds),
         f"total_ms={t_vec * 1e3:.1f}_speedup={t_seq / t_vec:.1f}x_"
         f"maxerr={err:.1e}")


def _week_trace():
    """The 7-day synthetic carbon trace shared by trace_sweep and
    optimize_sweep: diurnal swing + weekday drift + deterministic noise
    around the DTE grid factor."""
    from repro.core import DTE_FACTOR, TraceSignal

    rng = np.random.RandomState(7)
    h = np.arange(168)
    return TraceSignal(tuple(
        DTE_FACTOR * (1.0 + 0.30 * np.sin(2 * np.pi * h / 24.0)
                      + 0.08 * np.sin(2 * np.pi * h / 168.0)
                      + 0.05 * rng.randn(168))), name="week")


def trace_sweep():
    """Trace-grid scan engine (jitted jax.lax.scan over a 7-day carbon
    trace) vs sequential simulate_campaign at S in {10, 120, 1000} cases
    (acceptance bar: >=10x at S=1000, or document the measured ratio)."""
    from repro.core import (MachineProfile, SweepCase, calibrate_workload,
                            deadline_schedule, hourly_schedule,
                            simulate_campaign)
    from repro.core.engine_jax import _HAS_JAX, trace_sweep as run_trace
    from repro.core.workload import OEM_CASE_1

    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    trace = _week_trace()

    def cases_for(S):
        scheds = [hourly_schedule(f"hourly_{i}",
                                  [0.25 + 0.75 * ((5 * i + hh) % 24) / 23
                                   for hh in range(24)]) for i in range(S)]
        return [SweepCase(s, wl, m, carbon=trace) for s in scheds]

    backend = "jax" if _HAS_JAX else "numpy"
    for S in (10, 120, 1000):
        cases = cases_for(S)
        run_trace(cases, backend=backend)     # warm tables + jit cache
        t0 = time.perf_counter()
        vec = run_trace(cases, backend=backend)
        t_vec = time.perf_counter() - t0
        n_seq = min(S, 120)                   # sequential cost extrapolates
        t0 = time.perf_counter()
        seq = [simulate_campaign(c.workload, c.schedule, c.machine,
                                 carbon=trace) for c in cases[:n_seq]]
        t_seq = (time.perf_counter() - t0) * (S / n_seq)
        err = max(abs(a.co2_kg / b.co2_kg - 1)
                  for a, b in zip(vec[:n_seq], seq))
        emit(f"trace_sweep/{backend}_S{S}", t_vec * 1e6 / S,
             f"total_ms={t_vec * 1e3:.1f}_seq_ms={t_seq * 1e3:.1f}_"
             f"speedup={t_seq / t_vec:.1f}x_maxerr={err:.1e}")

    # a progress-aware fleet (deadline pace-keepers): the case family the
    # periodic engine cannot represent at all
    dls = [SweepCase(deadline_schedule(180.0 + 2.0 * i), wl, m, carbon=trace)
           for i in range(60)]
    run_trace(dls, backend=backend)
    t0 = time.perf_counter()
    run_trace(dls, backend=backend)
    t_vec = time.perf_counter() - t0
    t0 = time.perf_counter()
    for c in dls[:12]:
        simulate_campaign(c.workload, c.schedule, c.machine, carbon=trace,
                          deadline_h=c.deadline_h)
    t_seq = (time.perf_counter() - t0) * (len(dls) / 12)
    emit(f"trace_sweep/{backend}_deadline_60", t_vec * 1e6 / len(dls),
         f"total_ms={t_vec * 1e3:.1f}_seq_ms={t_seq * 1e3:.1f}_"
         f"speedup={t_seq / t_vec:.1f}x")


def ensemble_sweep():
    """Chunked trace engine + carbon-ensemble benchmarks (acceptance:
    the straggler re-scan is gone — >=3x reduction in scanned slot-work
    on a mixed-finish S=1000 batch — and repeated sweeps reuse the
    jitted chunk kernel instead of recompiling per shape).

    Rows: S x E ensemble scenario throughput; chunked-vs-monolithic
    slot-work ratio; jit-recompile count across repeated sweeps of
    varying batch sizes (bucketed padding keeps the signature set
    small)."""
    from repro.core import (MachineProfile, SweepCase, calibrate_workload,
                            hourly_schedule, trace_windows)
    from repro.core.engine_jax import (_HAS_JAX, reset_scan_stats,
                                       scan_stats, trace_sweep as run_trace)
    from repro.core.workload import OEM_CASE_1

    backend = "jax" if _HAS_JAX else "numpy"
    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())

    # --- S x E ensemble throughput -------------------------------------
    rng = np.random.RandomState(7)
    h = np.arange(24 * 7 * 7)
    series = 0.448 * (1.0 + 0.30 * np.sin(2 * np.pi * h / 24.0)
                      + 0.08 * np.sin(2 * np.pi * h / (24 * 7))
                      + 0.05 * rng.randn(len(h)))
    for S, E in ((32, 32), (120, 16)):
        ens = trace_windows(series, window_h=24 * 14, stride_h=24)
        assert len(ens) >= E, len(ens)
        ens = type(ens)(ens.members[:E], name=f"ens{E}")
        scheds = [hourly_schedule(f"e{i}",
                                  [0.3 + 0.65 * ((3 * i + hh) % 24) / 23
                                   for hh in range(24)]) for i in range(S)]
        cases = [SweepCase(s, wl, m, carbon=ens) for s in scheds]
        run_trace(cases, backend=backend)     # warm tables + jit cache
        t0 = time.perf_counter()
        res = run_trace(cases, backend=backend)
        dt = time.perf_counter() - t0
        emit(f"ensemble_sweep/{backend}_S{S}xE{E}", dt * 1e6 / (S * E),
             f"total_ms={dt * 1e3:.1f}_scenarios_per_s={S * E / dt:.0f}_"
             f"co2_std={res[0].co2_ensemble.std:.3f}")

    # --- chunked vs monolithic wasted work, mixed-finish S=1000 --------
    S = 1000
    scheds = [hourly_schedule(f"fast{i}",
                              [0.75 + 0.2 * ((i + hh) % 24) / 23
                               for hh in range(24)]) for i in range(S - 20)]
    scheds += [hourly_schedule(f"slow{i}", [0.12] * 24) for i in range(20)]
    cases = [SweepCase(s, wl, m) for s in scheds]
    for mode in ("chunked", "monolithic"):
        run_trace(cases, backend=backend, mode=mode)   # warm jit + plans
        reset_scan_stats()
        t0 = time.perf_counter()
        run_trace(cases, backend=backend, mode=mode)
        dt = time.perf_counter() - t0
        st = scan_stats()
        if mode == "chunked":
            work_chunked, t_chunked = st.slot_work, dt
        else:
            emit(f"ensemble_sweep/{backend}_straggler_S{S}",
                 t_chunked * 1e6 / S,
                 f"chunked_ms={t_chunked * 1e3:.0f}_mono_ms={dt * 1e3:.0f}_"
                 f"slot_work_ratio={st.slot_work / work_chunked:.1f}x_"
                 f"(bar>=3x)")

    # --- jit-recompile count across repeated, jittered sweeps ----------
    reset_scan_stats()
    for S in (64, 63, 61, 57, 49):            # same pow2 bucket: one shape
        sub = [SweepCase(s, wl, m) for s in scheds[:S]]
        run_trace(sub, backend=backend)
    st = scan_stats()
    emit(f"ensemble_sweep/{backend}_recompiles", 0.0,
         f"sweeps=5_jit_shapes={st.jit_compiles}_chunks={st.chunks}_"
         "(bucketed_padding_keeps_shapes_constant)")


def optimize_sweep():
    """Schedule-optimizer throughput (acceptance bar: a single jitted
    population step evaluates >=256 candidates; report candidates/sec for
    the jit and NumPy backends, and an end-to-end Campaign.optimize)."""
    from repro.core import (Campaign, MachineProfile, SweepCase,
                            calibrate_workload, parametric_schedule)
    from repro.core.engine_jax import _HAS_JAX, TraceObjective
    from repro.core.workload import OEM_CASE_1

    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    case = SweepCase(parametric_schedule(24), wl, m, deadline_h=220.0)
    rng = np.random.RandomState(0)
    for N in (256, 1024):
        U = 0.05 + 0.90 * rng.rand(N, 24)
        backends = (("jax",) if _HAS_JAX else ()) + ("numpy",)
        for backend in backends:
            to = TraceObjective(case, horizon_h=280.0, backend=backend)
            to.evaluate_batch(U)          # warm tables (+ jit cache)
            us = _t(lambda: to.evaluate_batch(U), n=3, warmup=1)
            emit(f"optimize_sweep/{backend}_pop{N}", us / N,
                 f"cands_per_s={N / (us / 1e6):.0f}_"
                 f"step_ms={us / 1e3:.1f}_slots={len(to.lens)}")

    trace = _week_trace()
    c = Campaign(OEM_CASE_1)
    t0 = time.perf_counter()
    res = c.optimize("energy", deadline_h=214.0, carbon_trace=trace,
                     candidates=256, iterations=30, steps=400,
                     method="auto" if _HAS_JAX else "cem")
    dt = time.perf_counter() - t0
    emit("optimize_sweep/campaign_end_to_end", dt * 1e6,
         f"method={res.method}_evals={res.evaluations}_"
         f"energy_kwh={res.result.energy_kwh:.2f}_"
         f"runtime_h={res.result.runtime_h:.1f}")


def fleet_sweep():
    """Grouped-lane fleet engine benchmarks (acceptance: the coupled
    grouped-lane sweep is >=10x faster than the python per-slot loop
    over campaigns at M=8, S=500, while agreeing with that oracle to
    <0.5%; bucketed padding keeps the coupled kernel's jit-shape count
    small across varying fleet widths)."""
    import dataclasses

    from repro.core import (MachineProfile, Site, SweepCase,
                            calibrate_workload, hourly_schedule)
    from repro.core.engine_jax import _HAS_JAX, reset_scan_stats, scan_stats
    from repro.core.fleet import fleet_sweep as run_fleet, simulate_fleet
    from repro.core.workload import OEM_CASE_1

    backend = "jax" if _HAS_JAX else "numpy"
    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    site = Site(power_cap_kw=2.0, office_kw=0.12)

    M, S = 8, 500
    wls = [dataclasses.replace(wl, name=f"wl{j}",
                               n_scenarios=int(wl.n_scenarios
                                               * (0.5 + 0.12 * j)))
           for j in range(M)]

    def group(i, width=M):
        s = hourly_schedule(f"f{i}", [0.35 + 0.6 * ((3 * i + h) % 24) / 23
                                      for h in range(24)])
        return [SweepCase(s, w, m, site.bands, None, 9.0,
                          label=f"f{i}/{w.name}") for w in wls[:width]]

    groups = [group(i) for i in range(S)]
    run_fleet(groups[:8], site, backend=backend)    # warm tables + jit
    reset_scan_stats()
    t0 = time.perf_counter()
    res = run_fleet(groups, site, backend=backend)
    dt = time.perf_counter() - t0
    # the python loop over campaigns: the sequential per-slot oracle,
    # timed on a subset and extrapolated (like the trace_sweep bench)
    n_seq = 3
    t0 = time.perf_counter()
    orcs = [simulate_fleet(grp, site) for grp in groups[:n_seq]]
    t_seq = (time.perf_counter() - t0) * (S / n_seq)
    err = max(abs(a.runtime_h / b.runtime_h - 1)
              for fr, orc in zip(res[:n_seq], orcs)
              for a, b in zip(fr.campaigns, orc.campaigns))
    emit(f"fleet_sweep/{backend}_M{M}xS{S}", dt * 1e6 / (M * S),
         f"total_ms={dt * 1e3:.0f}_campaigns_per_s={M * S / dt:.0f}_"
         f"pyloop_ms={t_seq * 1e3:.0f}_speedup={t_seq / dt:.1f}x_"
         f"(bar>=10x)_maxerr={err:.1e}_(bar<0.5%)_"
         f"peak_kw={res[0].site.peak_kw:.2f}")

    # jit recompiles across varying fleet widths: pow2 bucketing of both
    # the lane and the group axes keeps the signature set small
    reset_scan_stats()
    for width in (2, 3, 5, 8):
        sub = [group(i, width) for i in range(16)]
        run_fleet(sub, site, backend=backend)
    st = scan_stats()
    emit(f"fleet_sweep/{backend}_recompiles_varyM", 0.0,
         f"fleet_widths=4_jit_shapes={st.jit_compiles}_chunks={st.chunks}_"
         f"grouped_lanes={st.grouped_lanes}")


def serving_sweep():
    """Request-level serving scheduler benchmarks (acceptance: the
    vectorized window scheduler is >=10x faster than the per-request
    python FIFO loop it replaces at 10k+ requests; report scheduled+
    executed requests/sec per load shape, CO2 saved vs the carbon-blind
    FIFO at equal SLO attainment, and the jit-shape count across all
    four shapes — one window signature, no per-shape recompiles)."""
    from repro.core import (DTE_FACTOR, HourlySignal, LOAD_SHAPES,
                            MIDWEST_HOURLY, ServingSession, arrival_stream,
                            serve_window)
    from repro.core.engine_jax import reset_scan_stats, scan_stats
    from repro.core.serve import (DEFAULT_TIERS, FifoServingPolicy,
                                  _fifo_assign_loop)

    n = 20_000
    carbon = HourlySignal(tuple(float(v) * DTE_FACTOR
                                for v in MIDWEST_HOURLY))
    sess = ServingSession(carbon=carbon, service_rate=n * 3e-5,
                          start_hour=6.0)
    w = sess.window()
    batches = {s: arrival_stream(n, shape=s, seed=42, slack_h=(4.0, 12.0),
                                 camel_fracs=(0.2, 0.55),
                                 tier_mix=(0.8, 0.15, 0.05))
               for s in LOAD_SHAPES}
    serve_window(batches["random"], w, policy="greedy")  # warm tables + jit
    reset_scan_stats()
    for shape, batch in batches.items():
        t0 = time.perf_counter()
        fifo = serve_window(batch, w, policy="fifo")
        greedy = serve_window(batch, w, policy="greedy")
        dt = time.perf_counter() - t0
        saved = (1.0 - greedy.co2_kg / fifo.co2_kg) * 100.0
        emit(f"serving_sweep/{shape}_n{n}", dt * 1e6 / (2 * n),
             f"req_per_s={2 * n / dt:.0f}_co2_saved_vs_fifo={saved:.1f}%_"
             f"slo_miss={greedy.slo_miss_rate:.4f}_"
             f"admitted={greedy.n_admitted}/{n}")
    st = scan_stats()
    emit("serving_sweep/recompiles_4shapes", 0.0,
         f"windows=8_jit_shapes={st.jit_compiles}_chunks={st.chunks}_"
         f"requests_seen={st.requests_seen}")

    # the vectorized FIFO vs the per-request python loop it replaces
    batch = batches["random"]
    pol = FifoServingPolicy()
    us_vec = _t(lambda: pol.assign(batch, w, DEFAULT_TIERS), n=3, warmup=1)
    us_loop = _t(lambda: _fifo_assign_loop(batch, w, DEFAULT_TIERS),
                 n=3, warmup=1)
    emit(f"serving_sweep/fifo_vectorized_n{n}", us_vec / n,
         f"total_ms={us_vec / 1e3:.1f}_pyloop_ms={us_loop / 1e3:.1f}_"
         f"speedup={us_loop / us_vec:.1f}x_(bar>=10x)")


def _scaleout_worker(spec_json: str) -> None:
    """Subprocess body for `scaleout_sweep`: one (S, devices, precision)
    cell.  Runs in a fresh process because the virtual-device count is an
    XLA_FLAGS setting the parent fixed *before* this interpreter imported
    jax (see core/xla_profiles.py).  Prints a single JSON line."""
    import dataclasses

    from repro.core import (MachineProfile, SweepCase, calibrate_workload,
                            hourly_schedule)
    from repro.core.engine_jax import (compile_plan, execute_plan,
                                       reset_scan_stats, scan_stats)
    from repro.core.workload import OEM_CASE_1

    spec = json.loads(spec_json)
    S, devices, precision = spec["S"], spec["devices"], spec["precision"]
    reps = spec.get("reps", 1)
    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    # trim the campaign to ~2 days so one execute_plan is seconds, not
    # minutes, at S=1e5; the scan cost model (lanes x slots x buckets)
    # is unchanged
    wl = dataclasses.replace(wl, n_scenarios=300_000)
    trace = _week_trace()
    scheds = [hourly_schedule(f"sc{i}", [0.35 + 0.6 * ((3 * i + h) % 24) / 23
                                         for h in range(24)])
              for i in range(min(S, 64))]
    cases = [SweepCase(scheds[i % len(scheds)], wl, m, carbon=trace)
             for i in range(S)]
    plan = compile_plan(cases, progress_buckets=8, precision=precision)
    execute_plan(plan, devices=devices)           # warm the jit cache
    reset_scan_stats()
    t0 = time.perf_counter()
    for _ in range(reps):
        execute_plan(plan, devices=devices)
    dt = (time.perf_counter() - t0) / reps
    st = scan_stats()
    print(json.dumps({
        "S": S, "devices": devices, "precision": precision,
        "dt_s": dt, "scen_per_s": S / dt,
        "devices_used": st.devices_used,
        "precision_mode": st.precision_mode,
        "jax_devices": len(jax.devices()),
    }))


def scaleout_sweep():
    """Device fan-out + precision-policy scaling of the trace-scan engine
    (acceptance trajectory: >=3x scenarios/sec at 8 virtual CPU devices,
    S=1e5, plus a measured mixed-precision speedup with kWh/CO2 within
    1e-6 of fp64 — pinned separately by tests/test_scaleout.py).

    Each cell runs in a subprocess with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` because XLA
    reads the flag exactly once at backend init.  Virtual devices share
    the host's physical cores, so the achievable device speedup is
    bounded by ``host_cores`` — recorded in the JSON so single-core
    runs are not misread as regressions.  Besides the CSV rows, writes
    machine-readable ``BENCH_scaleout.json`` (path override:
    ``CARINA_BENCH_JSON``) for the CI artifact trail."""
    import subprocess

    from repro.core.xla_profiles import fanout_env

    host_cores = os.cpu_count() or 1
    s_values = (1_000, 10_000, 100_000)
    if os.environ.get("CARINA_BENCH_FAST"):
        s_values = (1_000, 10_000)
    grid = []
    for precision in ("fp64", "mixed"):
        for S in s_values:
            dev_counts = (1, 8)
            if S == s_values[-1] and precision == "fp64":
                dev_counts = (1, 2, 4, 8)
            for devices in dev_counts:
                grid.append((precision, S, devices))
    rows = []
    for precision, S, devices in grid:
        spec = {"S": S, "devices": devices, "precision": precision,
                "reps": 2 if S < 100_000 else 1}
        env = fanout_env(devices)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(ROOT, "src"), env.get("PYTHONPATH", "")])
        p = subprocess.run(
            [sys.executable, os.path.abspath(__file__),
             "_scaleout_worker", json.dumps(spec)],
            capture_output=True, text=True, env=env, timeout=1800)
        if p.returncode != 0:
            emit(f"scaleout_sweep/{precision}_S{S}_d{devices}", 0.0,
                 f"worker_failed_rc={p.returncode}")
            sys.stderr.write(p.stderr[-2000:] + "\n")
            continue
        rec = json.loads(p.stdout.strip().splitlines()[-1])
        rows.append(rec)
        emit(f"scaleout_sweep/{precision}_S{S}_d{devices}",
             rec["dt_s"] * 1e6 / S,
             f"scen_per_s={rec['scen_per_s']:.0f}_"
             f"total_ms={rec['dt_s'] * 1e3:.0f}_"
             f"devices_used={rec['devices_used']}")

    def rate(precision, S, devices):
        for r in rows:
            if (r["precision"], r["S"], r["devices"]) == (precision, S, devices):
                return r["scen_per_s"]
        return None

    speedups = {}
    for S in s_values:
        r1, r8 = rate("fp64", S, 1), rate("fp64", S, 8)
        if r1 and r8:
            speedups[f"fp64_S{S}_d8_vs_d1"] = r8 / r1
        rf, rm = rate("fp64", S, 1), rate("mixed", S, 1)
        if rf and rm:
            speedups[f"mixed_vs_fp64_S{S}_d1"] = rm / rf
    for key, val in sorted(speedups.items()):
        emit(f"scaleout_sweep/speedup_{key}", 0.0,
             f"x{val:.2f}_host_cores={host_cores}")
    out_path = os.environ.get("CARINA_BENCH_JSON", "BENCH_scaleout.json")
    with open(out_path, "w") as f:
        json.dump({"bench": "scaleout_sweep", "host_cores": host_cores,
                   "platform": jax.default_backend(),
                   "rows": rows, "speedups": speedups}, f, indent=2)
    emit("scaleout_sweep/json", 0.0, f"wrote_{out_path}_rows={len(rows)}")


def oem_case_studies():
    from repro.core import policy_frontier
    from repro.core.workload import OEM_CASE_1, OEM_CASE_2

    paper = {"oem-case-1": (48.67, 21.8, 44.3), "oem-case-2": (74.16, 33.2, 67.5)}
    for case in (OEM_CASE_1, OEM_CASE_2):
        t0 = time.perf_counter()
        res = {r.policy: r for r in policy_frontier(case)}
        us = (time.perf_counter() - t0) * 1e6
        b = res["baseline"]
        bo = res["peak_aware_boosted_offhours"]
        pk, pc, pb = paper[case.name]
        emit(f"oem/{case.name}/baseline", us / 2,
             f"kwh={b.energy_kwh:.2f}(paper {pk})_co2={b.co2_kg:.1f}(paper {pc})")
        emit(f"oem/{case.name}/boosted", us / 2,
             f"kwh={bo.energy_kwh:.2f}(paper~{pb})_co2={bo.co2_kg:.1f}")


def campaign_projection():
    """CARINA roofline-mode energy for a recurring retraining campaign on the
    production pod, per arch (uses dry-run step costs when available)."""
    from repro.core import EnergyModel, StepCost

    em = EnergyModel()
    files = sorted(glob.glob(os.path.join(
        ROOT, "experiments/dryrun/*.train_4k.pod16x16.json")))
    steps = 1000  # one scheduled retrain wave
    for f in files:
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            continue
        pc = rec["per_chip"]
        cost = StepCost(pc["hlo_flops"], pc["hlo_bytes"],
                        pc["collective_bytes"], chips=rec["chips"])
        t0 = time.perf_counter()
        j = em.step_energy_j(cost)
        us = (time.perf_counter() - t0) * 1e6
        kwh = j * steps / 3.6e6
        co2 = kwh * 0.448
        emit(f"campaign/{rec['arch']}", us,
             f"1000steps_kwh={kwh:.1f}_co2kg={co2:.1f}_"
             f"step={cost.step_seconds():.3f}s")


def roofline_table():
    files = sorted(glob.glob(os.path.join(ROOT, "experiments/dryrun/*.pod16x16.json")))
    for f in files:
        rec = json.load(open(f))
        if rec.get("status") == "skipped":
            emit(f"roofline/{rec['arch']}/{rec['shape']}", 0.0, "skipped")
            continue
        if rec.get("status") != "ok":
            emit(f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
                 f"status={rec.get('status')}")
            continue
        r = rec["roofline"]
        emit(f"roofline/{rec['arch']}/{rec['shape']}",
             r["step_seconds"] * 1e6,
             f"bottleneck={r['bottleneck']}_compute={r['compute_s']:.3f}s_"
             f"memory={r['memory_s']:.3f}s_coll={r['collective_s']:.3f}s_"
             f"useful={r['useful_flops_ratio']:.2f}")


def kernel_micro():
    from repro.models import layers as L
    from repro.models.loss import blocked_cross_entropy, cross_entropy
    from repro.models import ssm as SSM

    key = jax.random.PRNGKey(0)
    b, s, h, hkv, d = 1, 1024, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)

    dense = jax.jit(lambda q, k, v: L.attention(q, k, v, causal=True,
                                                chunk_q=10_000))
    chunked = jax.jit(lambda q, k, v: L.attention(q, k, v, causal=True,
                                                  chunk_q=256))
    us_d = _t(lambda: jax.block_until_ready(dense(q, k, v)))
    us_c = _t(lambda: jax.block_until_ready(chunked(q, k, v)))
    emit("kernel/attention_dense_1k", us_d, "xla_cpu")
    emit("kernel/attention_chunked_1k", us_c, f"ratio={us_c/us_d:.2f}")

    t, dd, vv = 2048, 256, 32000
    x = jax.random.normal(ks[0], (t, dd), jnp.float32) * 0.5
    emb = jax.random.normal(ks[1], (vv, dd), jnp.float32) * 0.5
    lab = jax.random.randint(ks[2], (t,), 0, vv)
    f_dense = jax.jit(lambda x, e: cross_entropy(
        jnp.einsum("td,vd->tv", x, e), lab)[0])
    f_blk = jax.jit(lambda x, e: blocked_cross_entropy(x, e, lab, block=4096)[0])
    us1 = _t(lambda: jax.block_until_ready(f_dense(x, emb)))
    us2 = _t(lambda: jax.block_until_ready(f_blk(x, emb)))
    emit("kernel/xent_dense_32k_vocab", us1, "materializes_TxV")
    emit("kernel/xent_blocked_32k_vocab", us2,
         f"ratio={us2/us1:.2f}_peak_mem_1/{vv//4096}x")

    a = jax.random.uniform(ks[0], (2, 2048, 512), jnp.float32, 0.5, 1.0)
    bb = jax.random.normal(ks[1], (2, 2048, 512)) * 0.1
    f_scan = jax.jit(lambda a, b: SSM.chunked_diag_scan(a, b, chunk=64)[0])
    us3 = _t(lambda: jax.block_until_ready(f_scan(a, bb)))
    emit("kernel/ssm_chunked_scan_2k", us3, "chunk=64")


def mpc_sweep():
    """Receding-horizon MPC loop cost (ISSUE 8): re-plan latency and
    solve-time amortization vs the control interval K, plus the
    zero-recompute ratio — slots carried across re-plans over total
    slots executed (1.0 = every re-plan resumed, nothing re-scanned)."""
    import dataclasses as _dc

    from repro.core import MachineProfile, SweepCase, calibrate_workload
    from repro.core.engine_jax import reset_scan_stats, scan_stats
    from repro.core.mpc import MPCSession
    from repro.core.policy import constant_schedule
    from repro.core.signal import as_trace
    from repro.core.workload import OEM_CASE_1

    rng = np.random.RandomState(17)
    h = np.arange(24 * 21, dtype=float)
    day = h // 24
    vals = (0.40 + (0.18 + 0.10 * np.sin(day * 2.1))
            * np.sin((h % 24) * 2 * np.pi / 24 + 0.8 * np.sin(day * 0.9))
            + 0.02 * rng.randn(h.size)).clip(0.05)
    truth = as_trace(tuple(vals), name="bench-truth")
    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    wl = _dc.replace(wl, n_scenarios=wl.n_scenarios // 8)
    case = SweepCase(constant_schedule(1.0), wl, m, carbon=truth,
                     start_hour=9.0, deadline_h=96.0)
    solver = dict(method="cem", candidates=24, iterations=4, seed=0)
    for K in (None, 24.0, 8.0, 4.0):
        reset_scan_stats()
        t0 = time.perf_counter()
        out = MPCSession(case, truth, constraints={"runtime_h": 96.0},
                         forecast="day_ahead", replan_every_h=K,
                         solver=solver).run()
        dt = time.perf_counter() - t0
        stats = scan_stats(reset=True)
        replan_us = (sum(r.solve_s for r in out.replans[1:]) * 1e6
                     / max(out.n_replans, 1))
        emit(f"mpc_sweep/K_{'inf' if K is None else int(K)}", dt * 1e6,
             f"replans={out.n_replans}_replan_ms={replan_us / 1e3:.0f}_"
             f"solve_frac={out.solve_s / dt:.2f}_"
             f"slots_reused={stats.slots_reused}_"
             f"co2_kg={out.realized_co2_kg:.3f}")


@_dataclasses.dataclass(frozen=True)
class _ProbeHeavySchedule:
    """A progress/elapsed-aware schedule with only a plain `decide()`
    (no `decide_grid`), so compilation pays the full probe + per-bucket
    table lowering — the recurrence bench's stand-in for the
    user-written python schedules whose compile cost the plan cache
    amortizes.  A frozen dataclass, so it fingerprints by value."""
    phase: float
    depth: float
    batch_size: int = 50

    @property
    def name(self) -> str:
        return f"probe-heavy[{self.phase:.3f}]"

    def decide(self, ctx):
        from repro.core import Decision
        u = (1.0 - self.depth * ctx.progress
             + 0.25 * np.sin(ctx.hour_of_day * 2 * np.pi / 24 + self.phase))
        return Decision(float(np.clip(u, 0.3, 1.0)), self.batch_size)


def _recurrence_worker(spec_json: str) -> None:
    """Subprocess body for `recurrence_sweep`: one full refresh cycle
    (compile + execute + summarize) in a fresh interpreter, against a
    shared on-disk plan cache.  Prints a single JSON line."""
    import dataclasses

    from repro.core import (MachineProfile, SweepCase, calibrate_workload,
                            trace_sweep)
    from repro.core.engine_jax import scan_stats
    from repro.core.workload import OEM_CASE_1

    spec = json.loads(spec_json)
    S = spec["S"]
    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    wl = dataclasses.replace(wl, n_scenarios=400.0)
    trace = _week_trace()
    cases = [SweepCase(_ProbeHeavySchedule(phase=0.37 * i, depth=0.5
                                           + 0.4 * i / S),
                       wl, m, carbon=trace, label=f"c{i}")
             for i in range(S)]
    t0 = time.perf_counter()
    res = trace_sweep(cases, backend="numpy", cache_dir=spec["cache_dir"])
    dt = time.perf_counter() - t0
    st = scan_stats()
    print(json.dumps({
        "S": S, "dt_s": dt,
        "plan_misses": st.plan_misses, "disk_hits": st.disk_hits,
        "co2_sum": sum(r.co2_kg for r in res)}))


def recurrence_sweep():
    """Recurrence as a cache hit (ISSUE 9): cold vs warm-process
    compile+sweep end-to-end (acceptance: >=5x — the warm process reads
    compiled tables off disk instead of re-probing S python schedules),
    plus the `delta_sweep` slot-work ratio at S=1000 for K changed
    schedules in {1, 10, 100} (acceptance at K=1, S=100: <=2% —
    pinned by tests/test_plancache.py; here the ratio is reported at
    production batch width).  Writes ``BENCH_recurrence.json`` (path
    override: ``CARINA_BENCH_RECURRENCE_JSON``)."""
    import dataclasses
    import shutil
    import subprocess
    import tempfile

    from repro.core import (MachineProfile, SweepCase, calibrate_workload,
                            constant_schedule)
    from repro.core.engine_jax import (compile_plan, delta_sweep,
                                       execute_plan, reset_scan_stats,
                                       scan_stats, summarize_plan)
    from repro.core.workload import OEM_CASE_1

    fast = bool(os.environ.get("CARINA_BENCH_FAST"))
    S_cycle = 24 if fast else 64
    cache_dir = tempfile.mkdtemp(prefix="carina-plancache-")
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), env.get("PYTHONPATH", "")])
    env.pop("CARINA_PLAN_CACHE", None)
    runs = {}
    try:
        for label in ("cold", "warm"):
            spec = {"S": S_cycle, "cache_dir": cache_dir}
            p = subprocess.run(
                [sys.executable, os.path.abspath(__file__),
                 "_recurrence_worker", json.dumps(spec)],
                capture_output=True, text=True, env=env, timeout=1800)
            if p.returncode != 0:
                emit(f"recurrence_sweep/{label}_S{S_cycle}", 0.0,
                     f"worker_failed_rc={p.returncode}")
                sys.stderr.write(p.stderr[-2000:] + "\n")
                return
            runs[label] = json.loads(p.stdout.strip().splitlines()[-1])
            emit(f"recurrence_sweep/{label}_S{S_cycle}",
                 runs[label]["dt_s"] * 1e6,
                 f"plan_misses={runs[label]['plan_misses']}_"
                 f"disk_hits={runs[label]['disk_hits']}")
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
    speedup = runs["cold"]["dt_s"] / max(runs["warm"]["dt_s"], 1e-9)
    bitwise = runs["cold"]["co2_sum"] == runs["warm"]["co2_sum"]
    emit(f"recurrence_sweep/warm_vs_cold_S{S_cycle}", 0.0,
         f"x{speedup:.1f}_(bar>=5x)_zero_compiles="
         f"{runs['warm']['plan_misses'] == 0}_bitwise={bitwise}")

    # delta-sweep slot-work ratios at production batch width
    S = 200 if fast else 1000
    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    wl = dataclasses.replace(wl, n_scenarios=400.0)
    trace = _week_trace()
    cases = [SweepCase(constant_schedule(0.35 + 0.65 * i / S), wl, m,
                       carbon=trace, label=f"c{i}")
             for i in range(S)]
    plan = compile_plan(cases)
    reset_scan_stats()
    state = execute_plan(plan, backend="numpy")
    base_work = scan_stats().slot_work
    prev = summarize_plan(plan, state)
    ratios = {}
    for K in (1, 10, 100):
        if K > S:
            continue
        deltas = {i: constant_schedule(0.9 - 0.4 * i / S)
                  for i in range(0, S, S // K)[:K]} if K > 1 else \
            {0: constant_schedule(0.9)}
        reset_scan_stats()
        t0 = time.perf_counter()
        delta_sweep(plan, prev, schedules=deltas, backend="numpy")
        dt = time.perf_counter() - t0
        st = scan_stats()
        ratios[f"K{K}"] = st.slot_work / max(base_work, 1)
        emit(f"recurrence_sweep/delta_S{S}_K{K}", dt * 1e6,
             f"slot_work_ratio={ratios[f'K{K}']:.4f}_"
             f"lanes_recomputed={st.lanes_recomputed}_"
             f"lanes_spliced={st.lanes_spliced}")

    out_path = os.environ.get("CARINA_BENCH_RECURRENCE_JSON",
                              "BENCH_recurrence.json")
    with open(out_path, "w") as f:
        json.dump({"bench": "recurrence_sweep", "S_cycle": S_cycle,
                   "cold": runs["cold"], "warm": runs["warm"],
                   "warm_vs_cold_speedup": speedup, "bitwise": bitwise,
                   "delta_S": S, "delta_slot_work_ratios": ratios},
                  f, indent=2)
    emit("recurrence_sweep/json", 0.0, f"wrote_{out_path}")


def calibration_sweep():
    """Measured-run calibration + the zone sweep axis (ISSUE 10): fit
    wall-time and recovered-parameter error at U in {1e3, 1e4}
    synthetic observations (the jax Adam path, plus the numpy
    finite-difference fallback at the small size), and the multi-zone
    (S, zone) batched sweep vs a per-zone python loop over the same
    archive.  Writes ``BENCH_calibration.json`` (path override:
    ``CARINA_BENCH_CALIBRATION_JSON``)."""
    import shutil
    import tempfile

    from repro.core import (Campaign, MachineProfile, constant_schedule,
                            load_carbon_archive, model,
                            write_synthetic_archive)
    from repro.core.calibrate import Observations, fit_calibration
    from repro.core.engine_jax import clear_plan_cache
    from repro.core.workload import OEMWorkload

    fast = bool(os.environ.get("CARINA_BENCH_FAST"))
    truth = {"rate_at_full": 3.4, "gamma": 0.65, "idle_w": 95.0,
             "dyn_w": 260.0, "overhead_w_frac": 0.45}
    rng = np.random.RandomState(0)

    def synth(n):
        """n synthetic operating points at the truth physics + 0.5%
        measurement noise (the U-scaling benches need logs far larger
        than any simulated campaign writes)."""
        u = 0.3 + 0.7 * rng.rand(n)
        batch = rng.choice([8.0, 16.0, 32.0, 64.0], size=n)
        bg = rng.choice([0.02, 0.15, 0.50, 0.65], size=n)
        r = model.rates(u, batch, bg,
                        rate_at_full=truth["rate_at_full"],
                        batch_overhead_s=2.0, idle_w=truth["idle_w"],
                        dyn_w=truth["dyn_w"], alpha=1.7,
                        gamma=truth["gamma"],
                        overhead_w_frac=truth["overhead_w_frac"], xp=np)
        return Observations(
            u=u, batch=batch, background=bg,
            scen_per_s=r.scen_per_s * (1.0 + 0.005 * rng.randn(n)),
            p_avg_w=r.p_avg_w * (1.0 + 0.005 * rng.randn(n)),
            weight=np.full(n, 1.0 / n))

    wl0 = OEMWorkload("bench", 1, rate_at_full=3.0, batch_overhead_s=2.0)
    m0 = MachineProfile()
    sizes = (1000,) if fast else (1000, 10_000)
    steps = 300 if fast else 500
    fits = {}
    for n in sizes:
        obs = synth(n)
        backends = ("jax", "numpy") if n == sizes[0] else ("jax",)
        for backend in backends:
            t0 = time.perf_counter()
            cm = fit_calibration(obs, wl0, m0, steps=steps,
                                 backend=backend)
            dt = time.perf_counter() - t0
            err = max(cm.rel_error(truth).values())
            emit(f"calibration_sweep/fit_U{n}_{backend}", dt * 1e6,
                 f"max_rel_err={err:.4f}_loss={cm.loss:.2e}")
            fits[f"U{n}_{backend}"] = {"dt_s": dt, "max_rel_err": err,
                                       "loss": cm.loss}

    # multi-zone batched sweep vs a per-zone python loop
    n_zones = 4 if fast else 8
    S = 8 if fast else 12
    d = tempfile.mkdtemp(prefix="carina-calib-bench-")
    try:
        arch = load_carbon_archive(write_synthetic_archive(
            os.path.join(d, "bench.csv"),
            zones=tuple(f"Z{i}" for i in range(n_zones)), days=7, seed=2))
        wl = OEMWorkload("zsweep", 40_000, rate_at_full=2.3,
                         batch_overhead_s=2.0)
        scheds = [constant_schedule(0.35 + 0.6 * i / max(S - 1, 1))
                  for i in range(S)]
        c = Campaign(wl)
        clear_plan_cache()
        t0 = time.perf_counter()
        rows = c.sweep(scheds, zones=arch)
        dt_batched = time.perf_counter() - t0
        clear_plan_cache()
        t0 = time.perf_counter()
        loop_rows = []
        for z in arch.zones:
            loop_rows.extend(c.sweep(scheds,
                                     carbon_trace=arch[z].to_trace()))
        dt_loop = time.perf_counter() - t0
    finally:
        shutil.rmtree(d, ignore_errors=True)
    scen = wl.n_scenarios * len(rows)
    bitwise = all(
        (a.runtime_h, a.energy_kwh, a.co2_kg)
        == (b.runtime_h, b.energy_kwh, b.co2_kg)
        for a, b in zip(rows, loop_rows))
    emit(f"calibration_sweep/zones_batched_S{S}_Z{n_zones}",
         dt_batched * 1e6, f"scen_per_s={scen / dt_batched:.0f}")
    emit(f"calibration_sweep/zones_loop_S{S}_Z{n_zones}", dt_loop * 1e6,
         f"scen_per_s={scen / dt_loop:.0f}")
    emit(f"calibration_sweep/zones_batched_vs_loop_S{S}_Z{n_zones}", 0.0,
         f"x{dt_loop / max(dt_batched, 1e-9):.1f}_bitwise={bitwise}")

    out_path = os.environ.get("CARINA_BENCH_CALIBRATION_JSON",
                              "BENCH_calibration.json")
    with open(out_path, "w") as f:
        json.dump({"bench": "calibration_sweep", "fits": fits,
                   "zones": {"S": S, "n_zones": n_zones,
                             "dt_batched_s": dt_batched,
                             "dt_loop_s": dt_loop,
                             "speedup": dt_loop / max(dt_batched, 1e-9),
                             "bitwise": bitwise}},
                  f, indent=2)
    emit("calibration_sweep/json", 0.0, f"wrote_{out_path}")


BENCHES = {
    "fig1_policy_frontier": fig1_policy_frontier,
    "frontier_sweep": frontier_sweep,
    "trace_sweep": trace_sweep,
    "ensemble_sweep": ensemble_sweep,
    "optimize_sweep": optimize_sweep,
    "fleet_sweep": fleet_sweep,
    "serving_sweep": serving_sweep,
    "scaleout_sweep": scaleout_sweep,
    "recurrence_sweep": recurrence_sweep,
    "calibration_sweep": calibration_sweep,
    "mpc_sweep": mpc_sweep,
    "oem_case_studies": oem_case_studies,
    "campaign_projection": campaign_projection,
    "roofline_table": roofline_table,
    "kernel_micro": kernel_micro,
}


def main(argv=None) -> None:
    """Run the named benchmarks (all of them with no arguments)."""
    if argv and argv[0] == "_scaleout_worker":
        _scaleout_worker(argv[1])
        return
    if argv and argv[0] == "_recurrence_worker":
        _recurrence_worker(argv[1])
        return
    names = argv if argv else list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        raise SystemExit(f"unknown benchmark(s) {unknown}; "
                         f"choose from {list(BENCHES)}")
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()


if __name__ == "__main__":
    main(sys.argv[1:])
